//! The heterogeneous coordinator (the paper's system contribution).
//!
//! Maps every layer of a network onto {cores, IMA, DW accelerator} under one
//! of the paper's four computation mappings (§V-C), drives the engine models,
//! and aggregates cycles/energy into the metrics every figure reports:
//!
//! * `CORES`      — optimized parallel software on the 8 cores (baseline);
//! * `IMA_cjobN`  — everything (incl. depth-wise, diagonal-mapped with
//!                  C_job = N) on the IMA; residuals on the cores;
//! * `HYBRID`     — point-wise on the IMA, depth-wise in software (the [8]
//!                  configuration), with HWC↔CHW marshaling;
//! * `IMA+DW`     — point-wise on the IMA, depth-wise on the dedicated
//!                  accelerator, residuals/ancillary on the cores.
//!
//! On top of the per-request model, [`scheduler`] serves *batches* across
//! the multi-array pool (requests pipelined over disjoint layer resources,
//! double-buffered activations), [`plan_cache`] memoizes TILE&PACK
//! placements so repeated inferences skip allocation entirely, and
//! [`timeline`] names the pool's contended resources (each core, the DW
//! accelerator, the IMA mux, the DMA and PCM-programming ports, every
//! array) — every batch emits a per-resource busy-interval profile the
//! serving arbiter intersects (and backfills) against its pool timeline.

pub mod executor;
pub mod l1_planner;
pub mod metrics;
pub mod plan_cache;
pub mod scheduler;
pub mod timeline;

pub use executor::{run_network, Executor};
pub use l1_planner::{plan as l1_plan, L1Plan};
pub use metrics::{LayerReport, RunReport};
pub use plan_cache::{PlanCache, PlanKey};
pub use scheduler::{run_batched, BatchConfig, BatchReport};
pub use timeline::{
    IntervalSet, ResMap, ReservationProfile, ResourceSpan, ResourceTimeline, TimelineStats,
};

/// The four computation mappings of Fig. 9 (+ Fig. 13's taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    Cores,
    ImaOnly { c_job: usize },
    Hybrid,
    ImaDw,
}

impl Strategy {
    pub fn label(&self) -> String {
        match self {
            Strategy::Cores => "CORES".into(),
            Strategy::ImaOnly { c_job } => format!("IMA_cjob{c_job}"),
            Strategy::Hybrid => "HYBRID".into(),
            Strategy::ImaDw => "IMA+DW".into(),
        }
    }

    /// The Fig. 9 line-up.
    pub fn paper_lineup() -> Vec<Strategy> {
        vec![
            Strategy::Cores,
            Strategy::ImaOnly { c_job: 8 },
            Strategy::ImaOnly { c_job: 16 },
            Strategy::Hybrid,
            Strategy::ImaDw,
        ]
    }
}

/// Which engine executes a layer under a strategy (used by reports and by
/// the functional runtime to issue the same job stream).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Cores,
    Ima,
    DwAcc,
}
