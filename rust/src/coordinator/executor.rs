//! Layer-to-engine scheduling and cost aggregation (the run loop).
//!
//! Layer-to-layer execution is sequential with all activations resident in
//! L1 (the paper's §VI model); within a layer the coordinator issues the
//! engine's job stream (pipelined on the IMA, blocks on the DW engine,
//! parallel sections on the cores) and charges any ancillary core work the
//! mapping implies (partial accumulation + requant for row-split IMA layers,
//! HWC↔CHW marshaling for HYBRID depth-wise).

use crate::arch::{EnergyAccount, PowerModel, SystemConfig};
use crate::cores::SwKernels;
use crate::dwacc;
use crate::ima::{ConvMap, DwMap, ImaSubsystem};
use crate::net::{Layer, LayerKind, Network};

use super::metrics::{LayerReport, RunReport};
use super::{Engine, Strategy};

pub struct Executor<'a> {
    pub cfg: &'a SystemConfig,
    pub pm: &'a PowerModel,
    pub strategy: Strategy,
}

impl<'a> Executor<'a> {
    pub fn new(cfg: &'a SystemConfig, pm: &'a PowerModel, strategy: Strategy) -> Self {
        Executor { cfg, pm, strategy }
    }

    fn sw(&self) -> SwKernels<'a> {
        SwKernels::new(self.cfg)
    }

    fn ima(&self) -> ImaSubsystem<'a> {
        ImaSubsystem::new(self.cfg, self.pm)
    }

    /// Cost one layer; returns (report, energy account).
    pub fn layer(&self, l: &Layer) -> (LayerReport, EnergyAccount) {
        match (l.kind, self.strategy) {
            // ---- convolutions -------------------------------------------
            (LayerKind::Conv, Strategy::Cores) => self.on_cores(l),
            (LayerKind::Conv, _) => self.conv_on_ima(l),

            // ---- depth-wise ---------------------------------------------
            (LayerKind::Dw, Strategy::Cores) => self.on_cores(l),
            (LayerKind::Dw, Strategy::ImaOnly { c_job }) => self.dw_on_ima(l, c_job),
            (LayerKind::Dw, Strategy::Hybrid) => self.dw_hybrid(l),
            (LayerKind::Dw, Strategy::ImaDw) => self.dw_on_accel(l),

            // ---- everything else stays on the cores ---------------------
            _ => self.on_cores(l),
        }
    }

    fn on_cores(&self, l: &Layer) -> (LayerReport, EnergyAccount) {
        let c = self.sw().layer_cost(l);
        (
            LayerReport {
                name: l.name.clone(),
                engine: Engine::Cores,
                cycles: c.cycles,
                energy_j: c.energy.total_j(self.pm, self.cfg),
                macs: l.macs(),
                ops: l.ops(),
                devices: 0,
                cores_used: c.cores,
            },
            c.energy,
        )
    }

    fn conv_on_ima(&self, l: &Layer) -> (LayerReport, EnergyAccount) {
        let ima = self.ima();
        let map = ConvMap::new(l, self.cfg.xbar_rows);
        let mut cost = ima.conv_layer_cost(&map);
        // row-split layers: cores accumulate int32 partials and requantize
        if map.row_split() {
            let elems = l.out_pixels() * l.cout;
            let acc = self.sw().accumulate_partials(elems, map.n_row_tiles);
            let rq = self.sw().requant(elems);
            cost.cycles += acc.cycles + rq.cycles;
            cost.energy.add(&acc.energy);
            cost.energy.add(&rq.energy);
        }
        (
            LayerReport {
                name: l.name.clone(),
                engine: Engine::Ima,
                cycles: cost.cycles,
                energy_j: cost.energy.total_j(self.pm, self.cfg),
                macs: l.macs(),
                ops: l.ops(),
                devices: map.devices_total(),
                // ancillary accumulation/requant rides inside the IMA
                // layer's serial cycles; the resource model charges the
                // arrays, not the cores (pre-existing simplification)
                cores_used: 0,
            },
            cost.energy,
        )
    }

    fn dw_on_ima(&self, l: &Layer, c_job: usize) -> (LayerReport, EnergyAccount) {
        let ima = self.ima();
        let map = DwMap::new(l, c_job);
        let cost = ima.dw_layer_cost(&map);
        (
            LayerReport {
                name: l.name.clone(),
                engine: Engine::Ima,
                cycles: cost.cycles,
                energy_j: cost.energy.total_j(self.pm, self.cfg),
                macs: l.macs(),
                ops: l.ops(),
                devices: map.devices_total(),
                cores_used: 0,
            },
            cost.energy,
        )
    }

    fn dw_hybrid(&self, l: &Layer) -> (LayerReport, EnergyAccount) {
        // software dw needs CHW: marshal the IMA's HWC output in, and the
        // result back to HWC for the next IMA layer (paper §V-C)
        let sw = self.sw();
        let m_in = sw.marshal(l.hin * l.win * l.cin);
        let dw = sw.layer_cost(l);
        let m_out = sw.marshal(l.out_pixels() * l.cout);
        let mut energy = EnergyAccount::default();
        energy.add(&m_in.energy);
        energy.add(&dw.energy);
        energy.add(&m_out.energy);
        let cycles = m_in.cycles + dw.cycles + m_out.cycles;
        (
            LayerReport {
                name: l.name.clone(),
                engine: Engine::Cores,
                cycles,
                energy_j: energy.total_j(self.pm, self.cfg),
                macs: l.macs(),
                ops: l.ops(),
                devices: 0,
                // sequential sections: reserve the widest one
                cores_used: m_in.cores.max(dw.cores).max(m_out.cores),
            },
            energy,
        )
    }

    fn dw_on_accel(&self, l: &Layer) -> (LayerReport, EnergyAccount) {
        let c = dwacc::dw_layer_cost(l, self.cfg, self.pm);
        (
            LayerReport {
                name: l.name.clone(),
                engine: Engine::DwAcc,
                cycles: c.cycles,
                energy_j: c.energy.total_j(self.pm, self.cfg),
                macs: l.macs(),
                ops: l.ops(),
                devices: 0,
                cores_used: 0,
            },
            c.energy,
        )
    }
}

/// Run a whole network under a strategy — the entry point every figure uses.
pub fn run_network(
    net: &Network,
    strategy: Strategy,
    cfg: &SystemConfig,
    pm: &PowerModel,
) -> RunReport {
    let ex = Executor::new(cfg, pm, strategy);
    let mut layers = Vec::with_capacity(net.layers.len());
    let mut total = EnergyAccount::default();
    for l in &net.layers {
        let (rep, acc) = ex.layer(l);
        layers.push(rep);
        total.add(&acc);
    }
    RunReport::from_parts(&net.name, strategy, cfg, pm, layers, &total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::bottleneck::bottleneck;

    fn run(strategy: Strategy) -> RunReport {
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        run_network(&bottleneck(), strategy, &cfg, &pm)
    }

    /// The Fig. 9 calibration — the paper's headline ratios must hold in
    /// shape: who wins, by roughly what factor.
    #[test]
    fn fig9_performance_ordering_and_ratios() {
        let cores = run(Strategy::Cores);
        let c8 = run(Strategy::ImaOnly { c_job: 8 });
        let c16 = run(Strategy::ImaOnly { c_job: 16 });
        let hy = run(Strategy::Hybrid);
        let id = run(Strategy::ImaDw);

        let r = |x: &RunReport| cores.cycles as f64 / x.cycles as f64;
        // ordering
        assert!(id.cycles < hy.cycles);
        assert!(hy.cycles < c16.cycles);
        assert!(c16.cycles < c8.cycles);
        assert!(c8.cycles <= cores.cycles);
        // bands around the paper's 1.23 / 2.27 / 4.6 / 11.5
        assert!((1.0..1.6).contains(&r(&c8)), "cjob8 {:.2}x", r(&c8));
        assert!((1.7..2.9).contains(&r(&c16)), "cjob16 {:.2}x", r(&c16));
        // IMA+DW lands at ~14–15× here vs the paper's 11.5× — the per-job
        // RTL overheads the silicon pays are not all recoverable from the
        // text; EXPERIMENTS.md discusses the deviation. The *shape* (order
        // of magnitude over CORES, ~3× over HYBRID) is the claim under test.
        assert!((3.4..6.0).contains(&r(&hy)), "hybrid {:.2}x", r(&hy));
        assert!((9.0..17.0).contains(&r(&id)), "ima+dw {:.2}x", r(&id));
        let id_vs_hy = hy.cycles as f64 / id.cycles as f64;
        assert!((2.0..4.0).contains(&id_vs_hy), "ima+dw/hybrid {id_vs_hy:.2}x (paper 2.6)");
    }

    #[test]
    fn fig9_energy_efficiency_ordering() {
        let cores = run(Strategy::Cores);
        let hy = run(Strategy::Hybrid);
        let id = run(Strategy::ImaDw);
        let c16 = run(Strategy::ImaOnly { c_job: 16 });
        assert!(id.tops_per_w() > hy.tops_per_w());
        assert!(hy.tops_per_w() > cores.tops_per_w());
        // paper: 9.2× CORES for IMA+DW, 3.4× for HYBRID
        let e_id = id.tops_per_w() / cores.tops_per_w();
        let e_hy = hy.tops_per_w() / cores.tops_per_w();
        assert!((6.0..14.0).contains(&e_id), "IMA+DW eff {e_id:.2}x");
        assert!((2.3..5.0).contains(&e_hy), "HYBRID eff {e_hy:.2}x");
        // paper: cjob16 energy efficiency "comparable" to CORES; our model
        // lands at ~2.9× (the analog fixed-energy share of near-empty jobs
        // is the dominant unknown — EXPERIMENTS.md). The claim under test:
        // dw-on-IMA efficiency is nowhere near IMA+DW's.
        let e_c16 = c16.tops_per_w() / cores.tops_per_w();
        assert!((0.4..5.5).contains(&e_c16), "cjob16 eff {e_c16:.2}x");
        assert!(e_id > 2.0 * e_c16, "IMA+DW must dwarf dw-on-IMA efficiency");
    }

    #[test]
    fn fig10_amdahl_story() {
        // CORES: pw dominates; IMA_cjob: dw dominates; IMA+DW: balanced
        let cores = run(Strategy::Cores);
        let pw_cy: u64 = cores.layers[0].cycles + cores.layers[2].cycles;
        assert!(pw_cy > cores.layers[1].cycles, "pw dominates in software");

        let c16 = run(Strategy::ImaOnly { c_job: 16 });
        let dw_cy = c16.layers[1].cycles;
        assert!(
            dw_cy > 2 * (c16.layers[0].cycles + c16.layers[2].cycles),
            "dw dominates on the IMA"
        );

        let id = run(Strategy::ImaDw);
        let parts: Vec<u64> = id.layers.iter().map(|l| l.cycles).collect();
        let max = *parts.iter().max().unwrap() as f64;
        let min = *parts.iter().min().unwrap() as f64;
        assert!(max / min < 25.0, "IMA+DW balanced: {parts:?}");
    }

    #[test]
    fn residual_always_on_cores() {
        for s in Strategy::paper_lineup() {
            let r = run(s);
            assert_eq!(r.layers[3].engine, Engine::Cores, "{s:?}");
        }
    }

    #[test]
    fn devices_accounting() {
        let id = run(Strategy::ImaDw);
        // pw expand + project mapped: 2 × 128 × 768 devices
        assert_eq!(id.devices_used, 2 * 128 * 768);
        let c16 = run(Strategy::ImaOnly { c_job: 16 });
        assert_eq!(c16.devices_used, 2 * 128 * 768 + 9 * 768 * 16);
    }
}
