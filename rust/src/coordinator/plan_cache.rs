//! TILE&PACK plan cache: repeated inferences skip allocation entirely.
//!
//! Placing a whole network is the expensive, offline half of serving
//! (hundreds of MaxRects scoring passes); the placement depends only on the
//! layer geometry and the pool shape. The cache keys on a fingerprint of
//! exactly those inputs and hands out shared, immutable plans (`Rc`), so a
//! cache hit is bit-identical to the miss that produced it — the scheduler
//! regression tests assert this, and the serving loop goes
//! allocation-free after the first request of each (network, pool) pair.

use std::cell::Cell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::net::Network;
use crate::tilepack::{place_staged, StagedPlacement};

/// What a placement depends on — nothing else may leak into the plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// FNV-1a over every layer's geometry (name excluded: renaming a layer
    /// must not fault the cache, resizing it must).
    pub net_fingerprint: u64,
    /// Crossbar side.
    pub s: usize,
    /// Pool size the plan was made for.
    pub n_arrays: usize,
    /// Whether 90° tile rotation was allowed.
    pub rotate: bool,
}

/// Geometry fingerprint of a network (delegates to [`Network::fingerprint`]).
pub fn fingerprint(net: &Network) -> u64 {
    net.fingerprint()
}

#[derive(Default)]
pub struct PlanCache {
    map: HashMap<PlanKey, Rc<StagedPlacement>>,
    hits: Cell<u64>,
    misses: Cell<u64>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Fetch the placement for (net, pool), computing it on first use.
    pub fn get_or_place(
        &mut self,
        net: &Network,
        s: usize,
        n_arrays: usize,
        rotate: bool,
    ) -> Result<Rc<StagedPlacement>, String> {
        let key = PlanKey {
            net_fingerprint: fingerprint(net),
            s,
            n_arrays,
            rotate,
        };
        if let Some(plan) = self.map.get(&key) {
            self.hits.set(self.hits.get() + 1);
            return Ok(Rc::clone(plan));
        }
        self.misses.set(self.misses.get() + 1);
        let plan = Rc::new(place_staged(net, s, n_arrays, rotate)?);
        self.map.insert(key, Rc::clone(&plan));
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::bottleneck::bottleneck;
    use crate::net::mobilenetv2::mobilenet_v2;

    #[test]
    fn fingerprint_sensitive_to_shape_not_name() {
        let a = bottleneck();
        let mut renamed = bottleneck();
        renamed.layers[0].name = "totally_different".into();
        assert_eq!(fingerprint(&a), fingerprint(&renamed));

        let mut resized = bottleneck();
        resized.layers[0].cout += 1;
        assert_ne!(fingerprint(&a), fingerprint(&resized));
    }

    #[test]
    fn hit_returns_the_same_plan_object() {
        let mut cache = PlanCache::new();
        let net = bottleneck();
        let first = cache.get_or_place(&net, 256, 8, false).unwrap();
        let second = cache.get_or_place(&net, 256, 8, false).unwrap();
        assert!(Rc::ptr_eq(&first, &second));
        assert_eq!((cache.misses(), cache.hits()), (1, 1));
        // bit-identical, not merely equal-by-pointer
        assert_eq!(*first, *second);
    }

    #[test]
    fn distinct_pools_are_distinct_entries() {
        let mut cache = PlanCache::new();
        let net = mobilenet_v2(224);
        let small = cache.get_or_place(&net, 256, 8, false).unwrap();
        let large = cache.get_or_place(&net, 256, 40, false).unwrap();
        assert!(small.n_passes() > 1);
        assert_eq!(large.n_passes(), 1);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.misses(), 2);
    }
}
