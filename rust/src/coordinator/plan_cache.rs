//! TILE&PACK plan cache: repeated inferences skip allocation entirely.
//!
//! Placing a whole network is the expensive, offline half of serving
//! (hundreds of MaxRects scoring passes); the placement depends only on the
//! layer geometry and the pool shape. The cache keys on a fingerprint of
//! exactly those inputs and hands out shared, immutable plans (`Rc`), so a
//! cache hit is bit-identical to the miss that produced it — the scheduler
//! regression tests assert this, and the serving loop goes
//! allocation-free after the first request of each (network, pool) pair.
//!
//! Multi-model serving keeps one cache alive across tenants and sweeps, so
//! the cache is LRU-bounded ([`PlanCache::with_capacity`]): evicting a plan
//! costs only recomputation, and because placement is a pure function of
//! the key, an evicted-then-recomputed plan is bit-identical to the one
//! evicted (pinned by the regression tests).
//!
//! The cache also interns **batch reports** ([`PlanCache::get_or_batch`]):
//! dispatching the same (plan, batch size, schedule flags) point re-runs
//! the whole list schedule and rebuilds its `ReservationProfile`, yet the
//! result is a pure function of those inputs — so the serving loop (and
//! sweeps sharing one cache) get every repeated batch's profile as one
//! shared `Rc` instead of recomputing and reallocating it per simulation.

use std::collections::HashMap;
use std::rc::Rc;

use crate::arch::{PowerModel, SystemConfig};
use crate::net::Network;
use crate::tilepack::{place_staged, StagedPlacement};

use super::scheduler::{run_batched, BatchConfig, BatchReport};
use super::Strategy;

/// What a placement depends on — nothing else may leak into the plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// FNV-1a over every layer's geometry (name excluded: renaming a layer
    /// must not fault the cache, resizing it must).
    pub net_fingerprint: u64,
    /// Crossbar side.
    pub s: usize,
    /// Pool size the plan was made for.
    pub n_arrays: usize,
    /// Whether 90° tile rotation was allowed.
    pub rotate: bool,
}

/// Geometry fingerprint of a network (delegates to [`Network::fingerprint`]).
pub fn fingerprint(net: &Network) -> u64 {
    net.fingerprint()
}

/// What one interned batch report depends on. The plan is identified by
/// the address of its shared allocation — sound because every memo entry
/// pins its plan `Rc`, so the address cannot be reused while the entry
/// lives (and two live plans never alias). The power-model fingerprint
/// and the config knobs the CLI can vary ride along; the remaining
/// calibrated `SystemConfig` constants never change at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct BatchKey {
    plan_ptr: usize,
    net_fingerprint: u64,
    pm_fingerprint: u64,
    strategy: Strategy,
    batch: usize,
    pipeline: bool,
    charge_dma: bool,
    stream_weights: bool,
    n_crossbars: usize,
    ima_bus_bits: usize,
    freq_mhz_bits: u64,
}

pub struct PlanCache {
    /// Key → (plan, last-touched tick) — recency is a monotone logical
    /// clock bumped on every lookup.
    map: HashMap<PlanKey, (Rc<StagedPlacement>, u64)>,
    /// Interned batch reports; the stored plan `Rc` pins the address the
    /// key carries. LRU-bounded like the plan map, at 8× the capacity
    /// (several batch sizes per plan).
    batch_map: HashMap<BatchKey, (Rc<BatchReport>, Rc<StagedPlacement>, u64)>,
    capacity: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    batch_hits: u64,
    batch_misses: u64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::with_capacity(usize::MAX)
    }
}

impl PlanCache {
    /// Unbounded cache (the single-model CLI paths).
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// LRU-bounded cache: at most `capacity` resident plans. Eviction only
    /// costs recomputation — placement is a pure function of the key.
    pub fn with_capacity(capacity: usize) -> PlanCache {
        assert!(capacity > 0, "plan cache capacity must be ≥ 1");
        PlanCache {
            map: HashMap::new(),
            batch_map: HashMap::new(),
            capacity,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            batch_hits: 0,
            batch_misses: 0,
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn batch_hits(&self) -> u64 {
        self.batch_hits
    }

    pub fn batch_misses(&self) -> u64 {
        self.batch_misses
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Fetch the placement for (net, pool), computing it on first use and
    /// evicting the least-recently-used plan when over capacity.
    pub fn get_or_place(
        &mut self,
        net: &Network,
        s: usize,
        n_arrays: usize,
        rotate: bool,
    ) -> Result<Rc<StagedPlacement>, String> {
        let key = PlanKey {
            net_fingerprint: fingerprint(net),
            s,
            n_arrays,
            rotate,
        };
        self.tick += 1;
        if let Some((plan, touched)) = self.map.get_mut(&key) {
            *touched = self.tick;
            self.hits += 1;
            return Ok(Rc::clone(plan));
        }
        self.misses += 1;
        let plan = Rc::new(place_staged(net, s, n_arrays, rotate)?);
        self.map.insert(key, (Rc::clone(&plan), self.tick));
        if self.map.len() > self.capacity {
            // evict the stalest entry (the one just inserted carries the
            // newest tick, so capacity ≥ 1 never evicts it)
            if let Some(oldest) = self
                .map
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| *k)
            {
                self.map.remove(&oldest);
                self.evictions += 1;
            }
        }
        Ok(plan)
    }

    /// Fetch the [`BatchReport`] (cycles, energy, reservation profile) of
    /// dispatching `batch` requests of `net` over `plan` — running the
    /// list schedule on first use and sharing the interned result on
    /// every repeat, so identical batches across a serving run (or across
    /// sweep points sharing this cache) hold one profile allocation. A
    /// hit is bit-identical to the miss that produced it: `run_batched`
    /// is a pure function of the key. Like plans, reports key on the
    /// geometry fingerprint, not names — a geometry-identical net sharing
    /// the plan gets a report whose `network`/`bottleneck_layer` strings
    /// are the first caller's (every numeric field is identical).
    #[allow(clippy::too_many_arguments)]
    pub fn get_or_batch(
        &mut self,
        net: &Network,
        strategy: Strategy,
        cfg: &SystemConfig,
        pm: &PowerModel,
        plan: &Rc<StagedPlacement>,
        cfgb: BatchConfig,
    ) -> Rc<BatchReport> {
        let key = BatchKey {
            plan_ptr: Rc::as_ptr(plan) as usize,
            // the plan records the fingerprint of the net it was placed
            // for (run_batched asserts they match), so no per-call
            // re-hash of every layer on the serving hot path
            net_fingerprint: plan.net_fingerprint,
            pm_fingerprint: pm.fingerprint(),
            strategy,
            batch: cfgb.batch,
            pipeline: cfgb.pipeline,
            charge_dma: cfgb.charge_dma,
            stream_weights: cfgb.stream_weights,
            n_crossbars: cfg.n_crossbars,
            ima_bus_bits: cfg.ima_bus_bits,
            freq_mhz_bits: cfg.freq.freq_mhz.to_bits(),
        };
        self.tick += 1;
        if let Some((rep, pinned, touched)) = self.batch_map.get_mut(&key) {
            debug_assert!(Rc::ptr_eq(pinned, plan), "aliased plan address");
            *touched = self.tick;
            self.batch_hits += 1;
            return Rc::clone(rep);
        }
        self.batch_misses += 1;
        let rep = Rc::new(run_batched(net, strategy, cfg, pm, plan, cfgb));
        self.batch_map.insert(key, (Rc::clone(&rep), Rc::clone(plan), self.tick));
        let cap = self.capacity.saturating_mul(8);
        if self.batch_map.len() > cap {
            if let Some(oldest) = self
                .batch_map
                .iter()
                .min_by_key(|(_, (_, _, t))| *t)
                .map(|(k, _)| *k)
            {
                self.batch_map.remove(&oldest);
            }
        }
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::bottleneck::bottleneck;
    use crate::net::mobilenetv2::mobilenet_v2;

    #[test]
    fn fingerprint_sensitive_to_shape_not_name() {
        let a = bottleneck();
        let mut renamed = bottleneck();
        renamed.layers[0].name = "totally_different".into();
        assert_eq!(fingerprint(&a), fingerprint(&renamed));

        let mut resized = bottleneck();
        resized.layers[0].cout += 1;
        assert_ne!(fingerprint(&a), fingerprint(&resized));
    }

    #[test]
    fn hit_returns_the_same_plan_object() {
        let mut cache = PlanCache::new();
        let net = bottleneck();
        let first = cache.get_or_place(&net, 256, 8, false).unwrap();
        let second = cache.get_or_place(&net, 256, 8, false).unwrap();
        assert!(Rc::ptr_eq(&first, &second));
        assert_eq!((cache.misses(), cache.hits()), (1, 1));
        // bit-identical, not merely equal-by-pointer
        assert_eq!(*first, *second);
    }

    #[test]
    fn distinct_pools_are_distinct_entries() {
        let mut cache = PlanCache::new();
        let net = mobilenet_v2(224);
        let small = cache.get_or_place(&net, 256, 8, false).unwrap();
        let large = cache.get_or_place(&net, 256, 40, false).unwrap();
        assert!(small.n_passes() > 1);
        assert_eq!(large.n_passes(), 1);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.evictions(), 0, "unbounded cache never evicts");
    }

    #[test]
    fn lru_evicts_least_recently_used_first() {
        let mut cache = PlanCache::with_capacity(2);
        let net = bottleneck();
        cache.get_or_place(&net, 256, 6, false).unwrap(); // A
        cache.get_or_place(&net, 256, 7, false).unwrap(); // B
        cache.get_or_place(&net, 256, 6, false).unwrap(); // touch A
        cache.get_or_place(&net, 256, 8, false).unwrap(); // C evicts B
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        // A stayed (it was touched), B went: re-fetching A hits, B misses
        let misses_before = cache.misses();
        cache.get_or_place(&net, 256, 6, false).unwrap();
        assert_eq!(cache.misses(), misses_before);
        cache.get_or_place(&net, 256, 7, false).unwrap();
        assert_eq!(cache.misses(), misses_before + 1);
    }

    #[test]
    fn interned_batch_reports_share_one_allocation() {
        let mut cache = PlanCache::new();
        let net = bottleneck();
        let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
        let cfg = SystemConfig::scaled_up(8);
        let pm = PowerModel::paper();
        let cfgb = BatchConfig {
            batch: 3,
            ..BatchConfig::default()
        };
        let a = cache.get_or_batch(&net, Strategy::ImaDw, &cfg, &pm, &plan, cfgb);
        let b = cache.get_or_batch(&net, Strategy::ImaDw, &cfg, &pm, &plan, cfgb);
        assert!(Rc::ptr_eq(&a, &b), "a repeat batch shares the report");
        assert_eq!((cache.batch_misses(), cache.batch_hits()), (1, 1));
        // a different point recomputes, bit-identical to a fresh schedule
        let big = BatchConfig {
            batch: 4,
            ..BatchConfig::default()
        };
        let c = cache.get_or_batch(&net, Strategy::ImaDw, &cfg, &pm, &plan, big);
        let fresh = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, big);
        assert_eq!(c.cycles, fresh.cycles);
        assert_eq!(c.profile, fresh.profile);
        assert_eq!(cache.batch_misses(), 2);
    }

    #[test]
    fn evicted_then_recomputed_plan_is_bit_identical() {
        let mut bounded = PlanCache::with_capacity(1);
        let net = bottleneck();
        let first = bounded.get_or_place(&net, 256, 8, false).unwrap();
        let keep = Rc::clone(&first); // outlives the eviction
        bounded.get_or_place(&net, 256, 6, false).unwrap(); // evicts the 8-array plan
        assert_eq!(bounded.evictions(), 1);
        let recomputed = bounded.get_or_place(&net, 256, 8, false).unwrap();
        assert!(!Rc::ptr_eq(&keep, &recomputed), "a fresh object");
        assert_eq!(*keep, *recomputed, "but bit-identical content");
    }
}
