//! HWPE streamer: 3-D strided address generation + re-aligner (paper §IV-A).
//!
//! `Stream3d` is the functional address generator (three nested loops with
//! configurable strides — the pattern the source/sink modules walk in TCDM);
//! `StreamerPort` adds the timing view: beats through the data port, FIFO
//! decoupling, re-aligner penalty for non-word-aligned bases. The IMA's
//! "virtual IM2COL" (paper Fig. 3a) is a `Stream3d` over (K, K, Cin).

/// Three-level strided pattern: for d0 in 0..len0 { for d1 in .. { for d2.. } }
/// emitting `word_bytes`-sized elements at `base + d0*s0 + d1*s1 + d2*s2`.
#[derive(Clone, Copy, Debug)]
pub struct Stream3d {
    pub base: usize,
    pub len: [usize; 3],
    pub stride: [isize; 3],
    pub elem_bytes: usize,
}

impl Stream3d {
    /// Contiguous 1-D stream.
    pub fn linear(base: usize, elems: usize, elem_bytes: usize) -> Self {
        Stream3d {
            base,
            len: [1, 1, elems],
            stride: [0, 0, elem_bytes as isize],
            elem_bytes,
        }
    }

    /// The IMA's virtual IM2COL for one output pixel at (oy, ox) of an HWC
    /// tensor: inner loop walks Cin contiguously, outer two walk the KxK
    /// window with row stride `w * cin` (paper Fig. 3a).
    pub fn im2col_window(
        base: usize,
        w: usize,
        cin: usize,
        k: usize,
        stride: usize,
        oy: usize,
        ox: usize,
    ) -> Self {
        let row_bytes = (w * cin) as isize;
        Stream3d {
            base: base + (oy * stride * w + ox * stride) * cin,
            len: [k, k, cin],
            stride: [row_bytes, cin as isize, 1],
            elem_bytes: 1,
        }
    }

    pub fn total_elems(&self) -> usize {
        self.len[0] * self.len[1] * self.len[2]
    }

    pub fn total_bytes(&self) -> usize {
        self.total_elems() * self.elem_bytes
    }

    /// Generate every address in order (tests / functional checks).
    pub fn addresses(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.total_elems());
        for d0 in 0..self.len[0] {
            for d1 in 0..self.len[1] {
                for d2 in 0..self.len[2] {
                    let off = d0 as isize * self.stride[0]
                        + d1 as isize * self.stride[1]
                        + d2 as isize * self.stride[2] * self.elem_bytes as isize;
                    out.push((self.base as isize + off) as usize);
                }
            }
        }
        out
    }

    /// Is the innermost run word-contiguous? (determines re-aligner work)
    pub fn inner_contiguous(&self) -> bool {
        self.stride[2] == 1 && self.elem_bytes == 1 || self.stride[2] == self.elem_bytes as isize
    }
}

/// Timing view of a source or sink stream through the shared data port.
#[derive(Clone, Copy, Debug)]
pub struct StreamerPort {
    pub port_bytes: usize,
    /// FIFO depth decouples bursts from memory stalls (paper §IV-A); the
    /// model charges its fill latency once per stream.
    pub fifo_depth: usize,
}

impl StreamerPort {
    pub fn new(port_bytes: usize) -> Self {
        StreamerPort {
            port_bytes,
            fifo_depth: 4,
        }
    }

    /// Cycles to move the whole pattern through the port. Contiguous inner
    /// runs move `port_bytes` per beat; non-contiguous inner runs degrade to
    /// one element group per beat (the re-aligner gathers at element rate).
    pub fn stream_cycles(&self, s: &Stream3d) -> u64 {
        let inner_bytes = s.len[2] * s.elem_bytes;
        let runs = (s.len[0] * s.len[1]) as u64;
        let setup = 2; // address-generator prime + first FIFO fill
        if s.inner_contiguous() {
            let beats_per_run = inner_bytes.div_ceil(self.port_bytes) as u64;
            // misaligned run base costs one extra re-aligner beat
            let misalign = if s.base % self.port_bytes != 0 { 1 } else { 0 };
            setup + runs * (beats_per_run + misalign)
        } else {
            setup + runs * s.len[2] as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn linear_addresses() {
        let s = Stream3d::linear(100, 4, 1);
        assert_eq!(s.addresses(), vec![100, 101, 102, 103]);
        assert_eq!(s.total_bytes(), 4);
    }

    #[test]
    fn im2col_window_walks_the_kxk_patch() {
        // 4x4 image, cin=2, k=3, stride=1, output pixel (0,0)
        let s = Stream3d::im2col_window(0, 4, 2, 3, 1, 0, 0);
        let a = s.addresses();
        assert_eq!(a.len(), 3 * 3 * 2);
        // first row of the window: channels of pixels (0,0),(0,1),(0,2)
        assert_eq!(&a[..6], &[0, 1, 2, 3, 4, 5]);
        // second row starts at pixel (1,0) = byte 8
        assert_eq!(a[6], 8);
    }

    #[test]
    fn im2col_stride2_offsets() {
        let s = Stream3d::im2col_window(0, 8, 4, 3, 2, 1, 2);
        // window origin = (1*2, 2*2) = pixel (2,4) → byte (2*8+4)*4 = 80
        assert_eq!(s.addresses()[0], 80);
    }

    #[test]
    fn contiguous_stream_beats() {
        let p = StreamerPort::new(16);
        let s = Stream3d::linear(0, 256, 1);
        assert_eq!(p.stream_cycles(&s), 2 + 16);
        // misaligned base costs one extra beat
        let s2 = Stream3d::linear(3, 256, 1);
        assert_eq!(p.stream_cycles(&s2), 2 + 17);
    }

    #[test]
    fn im2col_stream_timing_matches_window_rows() {
        let p = StreamerPort::new(16);
        // k=3, cin=128: 9 runs of 128 contiguous bytes = 9*8 beats + setup
        let s = Stream3d::im2col_window(0, 16, 128, 3, 1, 0, 0);
        assert_eq!(p.stream_cycles(&s), 2 + 9 * 8);
    }

    #[test]
    fn address_count_always_matches_total() {
        prop::check("stream3d_count", 128, |rng| {
            let s = Stream3d {
                base: rng.range_i64(0, 1024) as usize,
                len: [
                    rng.range_i64(1, 4) as usize,
                    rng.range_i64(1, 4) as usize,
                    rng.range_i64(1, 64) as usize,
                ],
                stride: [
                    rng.range_i64(0, 512) as isize,
                    rng.range_i64(0, 128) as isize,
                    1,
                ],
                elem_bytes: 1,
            };
            assert_eq!(s.addresses().len(), s.total_elems());
        });
    }
}
