//! Hardware Processing Engine (HWPE) framework (paper §IV-A).
//!
//! Both accelerators are wrapped in the standardized HWPE shell: a
//! memory-mapped *controller* (register file + ACQUIRE/TRIGGER protocol), an
//! accelerator-specific *engine*, and a *streamer* that turns 3-D strided
//! TCDM access patterns into coherent streams (with a re-aligner so the
//! memory system never sees misaligned accesses).

pub mod regfile;
pub mod streamer;

pub use regfile::{RegFile, RegfileError};
pub use streamer::{Stream3d, StreamerPort};
