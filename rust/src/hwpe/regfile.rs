//! HWPE controller register file with the ACQUIRE/TRIGGER protocol
//! (paper §IV-B): a core locks the accelerator, programs a job context,
//! triggers, and is notified through the event unit. The model tracks both
//! the functional state machine and the programming cost in cycles.

/// Special register offsets (mirroring the hwpe-doc convention).
pub const REG_ACQUIRE: u32 = 0x00;
pub const REG_TRIGGER: u32 = 0x04;
pub const REG_STATUS: u32 = 0x08;
/// First job-context register.
pub const REG_JOB_BASE: u32 = 0x40;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HwpeState {
    Idle,
    Acquired { owner: usize },
    Running { owner: usize },
}

#[derive(Debug, PartialEq, Eq)]
pub enum RegfileError {
    Busy(usize),
    NotOwner(usize),
    NoContext,
}

impl std::fmt::Display for RegfileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegfileError::Busy(owner) => write!(f, "accelerator busy (owned by core {owner})"),
            RegfileError::NotOwner(core) => write!(f, "core {core} does not own the accelerator"),
            RegfileError::NoContext => write!(f, "trigger while no job context programmed"),
        }
    }
}

impl std::error::Error for RegfileError {}

/// Latch-based register file + controller FSM.
#[derive(Debug, Clone)]
pub struct RegFile {
    state: HwpeState,
    regs: Vec<u32>,
    programmed: bool,
    /// peripheral-bus cycles consumed by control-plane traffic
    pub cfg_cycles: u64,
    /// cycles per control-interface access (peripheral interconnect hop)
    access_cy: u64,
}

impl RegFile {
    pub fn new(n_job_regs: usize) -> Self {
        RegFile {
            state: HwpeState::Idle,
            regs: vec![0; n_job_regs],
            programmed: false,
            cfg_cycles: 0,
            access_cy: 2,
        }
    }

    pub fn state(&self) -> HwpeState {
        self.state
    }

    /// Core reads ACQUIRE: locks if idle.
    pub fn acquire(&mut self, core: usize) -> Result<(), RegfileError> {
        self.cfg_cycles += self.access_cy;
        match self.state {
            HwpeState::Idle => {
                self.state = HwpeState::Acquired { owner: core };
                Ok(())
            }
            HwpeState::Acquired { owner } | HwpeState::Running { owner } => {
                Err(RegfileError::Busy(owner))
            }
        }
    }

    /// Core writes one job-context register.
    pub fn write_job_reg(&mut self, core: usize, idx: usize, val: u32) -> Result<(), RegfileError> {
        self.cfg_cycles += self.access_cy;
        match self.state {
            HwpeState::Acquired { owner } if owner == core => {
                self.regs[idx] = val;
                self.programmed = true;
                Ok(())
            }
            HwpeState::Acquired { owner } | HwpeState::Running { owner } => {
                Err(RegfileError::NotOwner(if owner == core { core } else { core }))
            }
            HwpeState::Idle => Err(RegfileError::NotOwner(core)),
        }
    }

    pub fn read_job_reg(&self, idx: usize) -> u32 {
        self.regs[idx]
    }

    /// Core writes TRIGGER: starts the engine.
    pub fn trigger(&mut self, core: usize) -> Result<(), RegfileError> {
        self.cfg_cycles += self.access_cy;
        match self.state {
            HwpeState::Acquired { owner } if owner == core => {
                if !self.programmed {
                    return Err(RegfileError::NoContext);
                }
                self.state = HwpeState::Running { owner: core };
                Ok(())
            }
            _ => Err(RegfileError::NotOwner(core)),
        }
    }

    /// Engine raises end-of-computation: back to idle, owner released.
    pub fn end_of_computation(&mut self) {
        self.state = HwpeState::Idle;
        self.programmed = false;
    }

    /// Cost of a full layer configuration: acquire + `n` register writes +
    /// trigger, in peripheral-bus cycles.
    pub fn layer_cfg_cost_cy(&self, n_regs: usize) -> u64 {
        self.access_cy * (n_regs as u64 + 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_program_trigger_happy_path() {
        let mut rf = RegFile::new(16);
        rf.acquire(0).unwrap();
        rf.write_job_reg(0, 3, 0xDEAD).unwrap();
        assert_eq!(rf.read_job_reg(3), 0xDEAD);
        rf.trigger(0).unwrap();
        assert_eq!(rf.state(), HwpeState::Running { owner: 0 });
        rf.end_of_computation();
        assert_eq!(rf.state(), HwpeState::Idle);
    }

    #[test]
    fn second_core_bounces_off_lock() {
        let mut rf = RegFile::new(4);
        rf.acquire(1).unwrap();
        assert_eq!(rf.acquire(2), Err(RegfileError::Busy(1)));
        assert!(rf.write_job_reg(2, 0, 1).is_err());
        assert!(rf.trigger(2).is_err());
    }

    #[test]
    fn trigger_without_context_rejected() {
        let mut rf = RegFile::new(4);
        rf.acquire(0).unwrap();
        assert_eq!(rf.trigger(0), Err(RegfileError::NoContext));
    }

    #[test]
    fn cfg_cycles_accumulate() {
        let mut rf = RegFile::new(8);
        rf.acquire(0).unwrap();
        for i in 0..8 {
            rf.write_job_reg(0, i, i as u32).unwrap();
        }
        rf.trigger(0).unwrap();
        assert_eq!(rf.cfg_cycles, 2 * (1 + 8 + 1));
        assert_eq!(rf.layer_cfg_cost_cy(8), rf.cfg_cycles);
    }

    #[test]
    fn relock_after_completion() {
        let mut rf = RegFile::new(2);
        rf.acquire(5).unwrap();
        rf.write_job_reg(5, 0, 9).unwrap();
        rf.trigger(5).unwrap();
        rf.end_of_computation();
        rf.acquire(6).unwrap();
        assert_eq!(rf.state(), HwpeState::Acquired { owner: 6 });
    }
}
