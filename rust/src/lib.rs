//! `imcc` — a heterogeneous in-memory computing cluster, reproduced in Rust.
//!
//! This library reproduces *A Heterogeneous In-Memory Computing Cluster For
//! Flexible End-to-End Inference of Real-World Deep Neural Networks*
//! (Garofalo et al., 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the cluster coordinator: a cycle/energy-accurate
//!   model of the PULP cluster (8 RISC-V cores, 512 kB TCDM, logarithmic
//!   interconnect), the analog In-Memory Accelerator (IMA) subsystem with
//!   sequential/pipelined execution and the multi-array scale-up pool
//!   ([`ima::pool`]), the depth-wise digital accelerator, the TILE&PACK
//!   multi-crossbar allocator with whole-network pool placement
//!   ([`tilepack::placement`]), the layer-to-engine scheduler with the
//!   paper's four mapping strategies plus the batched multi-array serving
//!   engine ([`coordinator::scheduler`]) and its memoizing plan cache
//!   ([`coordinator::plan_cache`]), the event-driven multi-model serving
//!   simulator ([`serve`]: open-loop traffic, pool tenancy with scheduler
//!   arbitration, dynamic batching, latency percentiles), the
//!   state-of-the-art baseline models, and the report generators for every
//!   figure/table in the paper (plus the `scaleup` pool-size × batch sweep
//!   and the `serving` load/latency tables).
//! * **L2/L1 (python/, build-time only)** — the quantized MobileNetV2 and the
//!   Pallas crossbar/depth-wise kernels, AOT-lowered to HLO text.
//! * **runtime/** performs *functional* end-to-end inference by issuing the
//!   same job stream the timing model accounts, through a native integer
//!   backend implementing the AOT ABI's numeric contract (the PJRT/`xla`
//!   client is unavailable offline). Golden-vector tests verify
//!   bit-exactness vs the JAX reference when `make artifacts` has run and
//!   **skip cleanly otherwise** — `cargo test -q` needs no artifacts.
//!
//! Start from [`coordinator::run_network`] for per-request experiments,
//! [`coordinator::scheduler::run_batched`] for batched multi-array serving,
//! or [`runtime::functional`] for functional inference; `DESIGN.md` maps
//! every module to the paper section it reproduces.

pub mod arch;
pub mod baselines;
pub mod coordinator;
pub mod cores;
pub mod dwacc;
pub mod hwpe;
pub mod ima;
pub mod net;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tilepack;
pub mod util;
