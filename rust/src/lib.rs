//! `imcc` — a heterogeneous in-memory computing cluster, reproduced in Rust.
//!
//! This library reproduces *A Heterogeneous In-Memory Computing Cluster For
//! Flexible End-to-End Inference of Real-World Deep Neural Networks*
//! (Garofalo et al., 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the cluster coordinator: a cycle/energy-accurate
//!   model of the PULP cluster (8 RISC-V cores, 512 kB TCDM, logarithmic
//!   interconnect), the analog In-Memory Accelerator (IMA) subsystem with
//!   sequential/pipelined execution, the depth-wise digital accelerator, the
//!   TILE&PACK multi-crossbar allocator, the layer-to-engine scheduler with
//!   the paper's four mapping strategies, the state-of-the-art baseline
//!   models, and the report generators for every figure/table in the paper.
//! * **L2/L1 (python/, build-time only)** — the quantized MobileNetV2 and the
//!   Pallas crossbar/depth-wise kernels, AOT-lowered to HLO text.
//! * **runtime/** bridges the two: it loads `artifacts/*.hlo.txt` through the
//!   PJRT C API (`xla` crate) and performs *functional* end-to-end inference
//!   bit-exactly matching the JAX golden vectors — Python never runs on the
//!   request path.
//!
//! Start from [`coordinator::run`] for end-to-end experiments or
//! [`runtime::functional`] for functional inference; `DESIGN.md` maps every
//! module to the paper section it reproduces.

pub mod arch;
pub mod baselines;
pub mod coordinator;
pub mod cores;
pub mod dwacc;
pub mod hwpe;
pub mod ima;
pub mod net;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod tilepack;
pub mod util;
