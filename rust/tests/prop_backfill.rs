//! Property tests for interval-set timelines and backfilling dispatch
//! (no artifacts needed).
//!
//! Invariants pinned on random inputs:
//!
//! * `IntervalSet` agrees with a boolean-coverage model and stays
//!   canonical (sorted, disjoint, non-adjacent) under random inserts;
//! * committed reservations of one resource never overlap, and on any
//!   one timeline state the backfilled `earliest_start` is never later
//!   than the envelope answer (busy intervals are subsets of envelopes);
//! * end-to-end on random t=0 backlogs: backfilled makespan ≤ envelope
//!   makespan ≤ serialized sum, with identical served totals and every
//!   per-resource utilization inside [0, 1].

use imcc::arch::PowerModel;
use imcc::coordinator::timeline::{
    IntervalSet, ProfileBuilder, ResMap, ReservationProfile, ResourceTimeline,
};
use imcc::net::bottleneck::bottleneck;
use imcc::serve::{simulate, BatchWindow, ModelTraffic, ServeConfig, TrafficModel};
use imcc::util::prop;
use imcc::util::rng::SplitMix64;

#[test]
fn interval_set_matches_a_boolean_coverage_model() {
    prop::check("interval_set_model", 64, |rng: &mut SplitMix64| {
        let mut set = IntervalSet::new();
        let mut model = [false; 128];
        for _ in 0..rng.range_i64(1, 20) {
            let a = rng.below(120);
            let b = a + 1 + rng.below(8);
            set.insert(a, b);
            for cell in model.iter_mut().take(b as usize).skip(a as usize) {
                *cell = true;
            }
        }
        set.check_invariants();
        for (i, &busy) in model.iter().enumerate() {
            let i = i as u64;
            assert_eq!(set.overlaps(i, i + 1), busy, "cell {i}");
        }
        let covered = model.iter().filter(|&&x| x).count() as u64;
        assert_eq!(set.total(), covered);
        if covered > 0 {
            assert!(model[set.start() as usize]);
            assert!(model[set.end() as usize - 1]);
        }
    });
}

/// A random canonical profile: a few resources, each with a few disjoint
/// non-adjacent busy intervals (built through `ProfileBuilder`, which
/// guarantees the canonical form the scheduler emits).
fn random_profile(rng: &mut SplitMix64) -> ReservationProfile {
    let mut b = ProfileBuilder::new();
    let n_res = rng.range_i64(1, 4) as usize;
    let mut len = 0u64;
    for ri in 0..n_res {
        // distinct resource per slot so per-resource occupancies (and the
        // accumulated `busy`) never overlap — the canonical form the
        // scheduler guarantees
        let res = ri * 5 + rng.below(5) as usize;
        let mut t = rng.below(50);
        for _ in 0..rng.range_i64(1, 3) {
            let s = t + rng.below(20);
            let e = s + 1 + rng.below(30);
            b.occupy(res, s, e);
            t = e + 2; // keep per-resource occupancies non-adjacent
        }
        len = len.max(t);
    }
    b.build(len)
}

#[test]
fn commits_never_overlap_and_backfill_dominates_envelope_per_state() {
    prop::check("backfill_dominates_envelope", 48, |rng: &mut SplitMix64| {
        let mut bf = ResourceTimeline::backfilling();
        let mut env = ResourceTimeline::envelope();
        let map = ResMap::default();
        for _ in 0..rng.range_i64(2, 12) {
            let p = random_profile(rng);
            let nb = rng.below(40);
            let t_bf = bf.earliest_start(&p, map, nb);
            let t_env = env.earliest_start(&p, map, nb);
            // identical commit histories (the envelope schedule replayed
            // into both): backfilling can only start earlier
            assert!(t_bf <= t_env, "{t_bf} > {t_env}");
            assert!(t_bf >= nb && t_env >= nb);
            // the envelope placement is conflict-free in both structures
            for s in &p.spans {
                for &(a, b) in &s.intervals {
                    assert!(
                        !bf.overlaps(s.res, t_env + a, t_env + b),
                        "double booking on res {}",
                        s.res
                    );
                }
            }
            bf.commit(t_env, &p, map);
            env.commit(t_env, &p, map);
            for s in &p.spans {
                // committed sets stay canonical
                let ivs = bf.intervals(s.res);
                for &(x, y) in &ivs {
                    assert!(x < y);
                }
                for w in ivs.windows(2) {
                    assert!(w[0].1 < w[1].0, "res {}: {:?}", s.res, ivs);
                }
                // busy work always fits below the envelope frontier, and
                // both disciplines agree on the aggregate accounting
                assert!(bf.busy_cycles(s.res) <= bf.free_at(s.res));
                assert_eq!(bf.busy_cycles(s.res), env.busy_cycles(s.res));
                assert_eq!(bf.free_at(s.res), env.free_at(s.res));
            }
        }
    });
}

#[test]
fn backfill_placements_fill_gaps_without_collisions() {
    // the backfilling discipline scheduled greedily against itself:
    // every placement it chooses must be conflict-free, and the committed
    // sets stay canonical — this is the discipline the serving arbiter
    // actually runs
    prop::check("backfill_self_schedule", 48, |rng: &mut SplitMix64| {
        let mut tl = ResourceTimeline::backfilling();
        for _ in 0..rng.range_i64(2, 14) {
            let p = random_profile(rng);
            let nb = rng.below(60);
            let t = tl.earliest_start(&p, ResMap::default(), nb);
            assert!(t >= nb);
            for s in &p.spans {
                for &(a, b) in &s.intervals {
                    assert!(
                        !tl.overlaps(s.res, t + a, t + b),
                        "earliest_start returned a colliding placement on res {}",
                        s.res
                    );
                }
            }
            tl.commit(t, &p, ResMap::default());
            for s in &p.spans {
                let ivs = tl.intervals(s.res);
                for w in ivs.windows(2) {
                    assert!(w[0].1 < w[1].0, "res {}: {:?}", s.res, ivs);
                }
                let total: u64 = ivs.iter().map(|&(a, b)| b - a).sum();
                assert_eq!(total, tl.busy_cycles(s.res), "res {}", s.res);
                assert_eq!(ivs.last().map(|&(_, b)| b), Some(tl.free_at(s.res)));
            }
        }
    });
}

#[test]
fn backfill_le_envelope_le_serialized_on_random_backlogs() {
    prop::check("backfill_conservation", 8, |rng: &mut SplitMix64| {
        let pm = PowerModel::paper();
        let n_models = rng.range_i64(1, 4) as usize;
        let n_req = rng.range_i64(1, 11) as usize;
        let max_batch = rng.range_i64(1, 7) as usize;
        let pipeline = rng.below(2) == 1;
        let models: Vec<ModelTraffic> = (0..n_models)
            .map(|i| {
                let mut net = bottleneck();
                net.name = format!("bn-{i}");
                ModelTraffic {
                    net,
                    traffic: TrafficModel::Trace {
                        arrivals_cy: vec![0; n_req],
                    },
                    weight: 1,
                }
            })
            .collect();
        let base = ServeConfig {
            n_arrays: 8 * n_models,
            window: BatchWindow {
                max_batch,
                max_wait_cy: 0,
            },
            pipeline,
            duration_s: 0.01,
            ..ServeConfig::default()
        };
        let bf = simulate(&models, &base, &pm).unwrap();
        let env = simulate(
            &models,
            &ServeConfig {
                backfill: false,
                ..base.clone()
            },
            &pm,
        )
        .unwrap();
        let ser = simulate(
            &models,
            &ServeConfig {
                overlap: false,
                ..base
            },
            &pm,
        )
        .unwrap();

        // identical work in all three disciplines
        let total = (n_models * n_req) as u64;
        assert_eq!(bf.total_served(), total);
        assert_eq!(env.total_served(), total);
        assert_eq!(ser.total_served(), total);

        // the conservation chain the ISSUE pins: backfilled ≤ envelope ≤
        // serialized sum
        let sum: u64 = ser.tenants.iter().map(|t| t.busy_cycles).sum();
        assert_eq!(ser.makespan_cycles, sum, "serialized pool is back-to-back");
        assert!(
            env.makespan_cycles <= ser.makespan_cycles,
            "envelope {} > serialized {} (models {n_models}, req {n_req}, batch {max_batch})",
            env.makespan_cycles,
            ser.makespan_cycles
        );
        assert!(
            bf.makespan_cycles <= env.makespan_cycles,
            "backfilled {} > envelope {} (models {n_models}, req {n_req}, batch {max_batch})",
            bf.makespan_cycles,
            env.makespan_cycles
        );

        // busy ≤ makespan, per pool and per resource
        assert!(bf.busy_cycles <= bf.makespan_cycles);
        for r in &bf.resource_busy {
            let u = bf.resource_utilization(r);
            assert!((0.0..=1.0).contains(&u), "{} at {u}", r.name);
        }
    });
}
