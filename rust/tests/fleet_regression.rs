//! Regression tests for fleet-scale sharding (`serve::fleet`).
//!
//! Four contracts:
//! 1. **Single-node degeneration** — `--nodes 1` under *any* router is
//!    bit-identical to the single-cluster path: dispatch tables, serve
//!    JSON, and exported Chrome-trace bytes.
//! 2. **Router determinism** — every router policy is a pure function
//!    of the seed: two identical runs produce byte-identical reports,
//!    and routing conserves arrivals (served + dropped + rejected ==
//!    offered, summed over nodes).
//! 3. **Load-aware routing pays** — on a skewed hot spot (one heavy
//!    tenant, heterogeneous pools, the hash ring pinning it to the
//!    smallest node) least-loaded routing strictly beats hash routing
//!    on the merged p95.
//! 4. **Migration price accountability** — a cross-node migration's
//!    PCM reprogramming charge is independently recomputable from the
//!    destination's placement, and the hand-off charge is exactly
//!    `moved × handoff_cy_per_req`.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::PlanCache;
use imcc::ima::pool::ImaArrayPool;
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::serve::trace::chrome_trace;
use imcc::serve::{
    bottleneck_fleet, mnv2_bottleneck_pair, place_tenants, simulate_fleet, simulate_fleet_traced,
    simulate_traced, FleetConfig, FleetMigrationConfig, ModelTraffic, RouterPolicy, ServeConfig,
    TraceRecorder, TrafficModel,
};

const ROUTERS: [RouterPolicy; 3] = [
    RouterPolicy::Hash,
    RouterPolicy::LeastLoaded,
    RouterPolicy::Replica,
];

/// One hot MobileNetV2 tenant — the skewed-fleet workload: its resident
/// footprint fits a big node but forces staging on a small one, so where
/// the router puts it decides the tail.
fn hot_mnv2(rate_per_s: f64) -> Vec<ModelTraffic> {
    vec![ModelTraffic {
        net: mobilenet_v2(224),
        traffic: TrafficModel::Poisson { rate_per_s },
        weight: 1,
    }]
}

#[test]
fn single_node_fleet_is_bit_identical_to_the_single_cluster_path() {
    let pm = PowerModel::paper();
    let models = mnv2_bottleneck_pair(120.0);
    let scfg = ServeConfig {
        n_arrays: 64,
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    // the pinned baseline: the exact call `imcc serve --trace` makes
    let mut cache = PlanCache::with_capacity(scfg.plan_cache_cap);
    let mut rec = TraceRecorder::on(1 << 20);
    let base = simulate_traced(&models, &scfg, &pm, &mut cache, &mut rec).expect("baseline");
    let base_trace = chrome_trace(&base, &rec.finish().expect("recorder was on"))
        .to_string_pretty();

    for router in ROUTERS {
        let fcfg = FleetConfig::new(1, router);
        let mut recs = vec![TraceRecorder::on(1 << 20)];
        let rep = simulate_fleet_traced(&models, &scfg, &fcfg, &pm, &mut recs)
            .expect("single-node fleet");
        assert_eq!(rep.nodes.len(), 1);
        assert!(
            rep.migrations.is_empty(),
            "{router:?}: one node has nowhere to migrate"
        );
        let nr = &rep.nodes[0].report;
        assert_eq!(
            nr.render_table(),
            base.render_table(),
            "{router:?}: dispatch table"
        );
        assert_eq!(
            nr.to_json().to_string_pretty(),
            base.to_json().to_string_pretty(),
            "{router:?}: serve JSON bytes"
        );
        let tr = recs.remove(0).finish().expect("recorder was on");
        assert_eq!(
            chrome_trace(nr, &tr).to_string_pretty(),
            base_trace,
            "{router:?}: chrome-trace bytes"
        );
    }
}

#[test]
fn every_router_is_deterministic_and_conserves_arrivals() {
    let pm = PowerModel::paper();
    let models = bottleneck_fleet(5, 250.0);
    let scfg = ServeConfig {
        n_arrays: 32,
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    for router in ROUTERS {
        let mut fcfg = FleetConfig::new(4, router);
        // heterogeneous, but every pool fits its worst-case roster (the
        // hash ring sends all five tenants to one node)
        fcfg.node_arrays = vec![32, 24, 24, 32];
        let a = simulate_fleet(&models, &scfg, &fcfg, &pm).expect("run a");
        let b = simulate_fleet(&models, &scfg, &fcfg, &pm).expect("run b");
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "{router:?}: fleet JSON must be a pure function of the seed"
        );
        assert_eq!(a.render_table(), b.render_table(), "{router:?}: fleet table");
        // conservation over the whole fleet
        assert!(a.total_arrivals() > 0, "{router:?}: traffic generated");
        assert_eq!(
            a.total_arrivals(),
            a.total_served() + a.total_dropped() + a.total_rejected(),
            "{router:?}: routing must conserve arrivals"
        );
        // …and per node: the ledger travels with the requests
        for nr in &a.nodes {
            let arrivals: u64 = nr.report.tenants.iter().map(|t| t.arrivals).sum();
            let accounted = nr.report.total_served()
                + nr.report.total_dropped()
                + nr.report.total_rejected();
            assert_eq!(arrivals, accounted, "{router:?}: node {}", nr.node);
        }
    }
}

#[test]
fn least_loaded_routing_beats_hash_on_a_skewed_hot_spot() {
    let pm = PowerModel::paper();
    let models = hot_mnv2(400.0);
    let scfg = ServeConfig {
        n_arrays: 64,
        duration_s: 0.03,
        ..ServeConfig::default()
    };
    let node_arrays = vec![64, 32, 12, 64];
    let mut run = |router: RouterPolicy| {
        let mut fcfg = FleetConfig::new(4, router);
        fcfg.node_arrays = node_arrays.clone();
        simulate_fleet(&models, &scfg, &fcfg, &pm).expect("fleet run")
    };

    let hash = run(RouterPolicy::Hash);
    let ll = run(RouterPolicy::LeastLoaded);

    // the consistent-hash ring pins the tenant to node 2 — the 12-array
    // pool where MobileNetV2 cannot sit resident and every request pays
    // staged reprogramming
    let served_on = |rep: &imcc::serve::FleetReport, node: usize| -> u64 {
        rep.nodes[node].report.total_served()
    };
    assert_eq!(
        served_on(&hash, 2),
        hash.total_served(),
        "hash must pin the hot tenant to the ring's node 2"
    );
    // least-loaded weighs load against capacity and lands on a 64-array
    // node, where the tenant is resident
    assert_eq!(
        served_on(&ll, 0),
        ll.total_served(),
        "least-loaded must place the hot tenant on the big node 0"
    );
    assert_eq!(hash.total_arrivals(), ll.total_arrivals(), "same offered load");

    let p95_hash = hash.merged_latency().quantile(0.95);
    let p95_ll = ll.merged_latency().quantile(0.95);
    assert!(
        p95_ll < p95_hash,
        "load-aware routing must strictly beat the skewed hash pin \
         (p95 {p95_ll} !< {p95_hash} cycles)"
    );
}

#[test]
fn migration_price_is_independently_recomputable() {
    let pm = PowerModel::paper();
    let models = hot_mnv2(400.0);
    let scfg = ServeConfig {
        n_arrays: 12,
        duration_s: 0.04,
        ..ServeConfig::default()
    };
    let mut fcfg = FleetConfig::new(2, RouterPolicy::LeastLoaded);
    fcfg.node_arrays = vec![12, 12];
    // aggressive trip point, one-shot cooldown: the overloaded staged
    // tenant must migrate exactly once
    fcfg.migration = FleetMigrationConfig {
        hot_factor: 1,
        hot_margin: 2,
        window_cy: 100_000,
        cooldown_cy: 1_000_000_000_000,
        handoff_cy_per_req: 512,
    };
    let rep = simulate_fleet(&models, &scfg, &fcfg, &pm).expect("fleet run");

    assert_eq!(rep.migrations.len(), 1, "exactly one migration fires");
    let m = &rep.migrations[0];
    assert_eq!(m.tenant, "mobilenetv2");
    assert_eq!(m.from_node, 0, "ties in the load assignment keep node 0");
    assert_eq!(m.to_node, 1);
    assert!(m.moved > 0, "pending requests travelled");
    assert_eq!(
        m.handoff_cycles,
        m.moved as u64 * 512,
        "hand-off is priced per moved request"
    );
    assert!(!m.streamed, "no --stream-weights, the price blocks");
    assert!(
        m.blocked_cycles >= m.handoff_cycles,
        "the dispatch floor covers at least the hand-off tail"
    );

    // recompute the PCM reprogramming price from scratch: the
    // destination's standby placement of the tenant, first pass, summed
    // over the arrays it touches — the same model `apply_scale` charges
    let cfg = SystemConfig::scaled_up(12);
    let mut cache = PlanCache::with_capacity(scfg.plan_cache_cap);
    let nets = [mobilenet_v2(224)];
    let tenancy =
        place_tenants(&nets, cfg.xbar_rows, 12, scfg.rotate, &mut cache).expect("placement");
    let pool = ImaArrayPool::new(&cfg, &pm);
    let expect: u64 = pool
        .program_cycles_by_array(&tenancy.tenants[0].plan.passes[0])
        .values()
        .sum();
    assert!(expect > 0, "a staged tenant always reprograms");
    assert_eq!(m.program_cycles, expect, "PCM price recomputed from scratch");

    // the ledger moved with the requests: conservation holds fleet-wide
    // and the destination really served the handed-off stream
    assert_eq!(
        rep.total_arrivals(),
        rep.total_served() + rep.total_dropped() + rep.total_rejected()
    );
    assert!(
        rep.nodes[1].report.total_served() > 0,
        "the destination served the moved requests"
    );
}
