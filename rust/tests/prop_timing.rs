//! Timing-model validation: the closed-form steady-state pipeline estimate
//! (used for million-job layers) must agree with the exact greedy schedule
//! on every job stream MobileNetV2 actually issues.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::ima::{ConvMap, ImaSubsystem};
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::net::LayerKind;
use imcc::sim::pipeline::{schedule_pipelined, steady_state_pipelined};

#[test]
fn steady_state_matches_exact_on_every_mnv2_conv_layer() {
    let cfg = SystemConfig::paper();
    let pm = PowerModel::paper();
    let ima = ImaSubsystem::new(&cfg, &pm);
    let net = mobilenet_v2(224);
    for l in net.layers.iter().filter(|l| l.kind == LayerKind::Conv) {
        let map = ConvMap::new(l, 256);
        for rt_i in 0..map.n_row_tiles {
            for ct_i in 0..map.n_col_tiles {
                let job = map.job(rt_i, ct_i);
                let phases = ima.phases(&job, false);
                // cap n for the exact scheduler's O(n) cost
                let n = (map.pixels as u64).min(4096);
                let exact = schedule_pipelined((0..n).map(|_| phases).collect());
                let est = steady_state_pipelined(n, phases);
                let fill = phases.issue + phases.stream_in + phases.compute + phases.stream_out;
                assert!(
                    est.makespan.abs_diff(exact.makespan) <= fill,
                    "{}: est {} vs exact {} (fill {fill})",
                    l.name,
                    est.makespan,
                    exact.makespan
                );
                // relative error under 1% for real job counts
                let rel = est.makespan.abs_diff(exact.makespan) as f64 / exact.makespan as f64;
                assert!(rel < 0.01, "{}: rel err {rel}", l.name);
            }
        }
    }
}

#[test]
fn conv_cost_monotone_in_every_dimension() {
    // sanity surface: more pixels / rows / cols never get cheaper
    let cfg = SystemConfig::paper();
    let pm = PowerModel::paper();
    let ima = ImaSubsystem::new(&cfg, &pm);
    let base = imcc::net::Layer::conv("b", 16, 16, 64, 64);
    let cost = |l: &imcc::net::Layer| ima.conv_layer_cost(&ConvMap::new(l, 256)).cycles;
    let c0 = cost(&base);
    let bigger_spatial = imcc::net::Layer::conv("s", 32, 32, 64, 64);
    let more_cin = imcc::net::Layer::conv("ci", 16, 16, 128, 64);
    let more_cout = imcc::net::Layer::conv("co", 16, 16, 64, 128);
    assert!(cost(&bigger_spatial) > c0);
    assert!(cost(&more_cin) >= c0);
    assert!(cost(&more_cout) >= c0);
}

#[test]
fn e2e_cycles_equal_sum_of_layer_cycles() {
    // the RunReport aggregation invariant
    let cfg = SystemConfig::scaled_up(33);
    let pm = PowerModel::paper();
    let net = mobilenet_v2(224);
    let rep = imcc::coordinator::run_network(&net, imcc::coordinator::Strategy::ImaDw, &cfg, &pm);
    let sum: u64 = rep.layers.iter().map(|l| l.cycles).sum();
    assert_eq!(sum, rep.cycles);
    assert_eq!(rep.layers.len(), net.layers.len());
}
