//! Overlapped-dispatch regression tests (no artifacts needed).
//!
//! Pins the acceptance criteria of the per-resource contention model:
//! two resident tenants on disjoint slices achieve an overlapped makespan
//! strictly below the serialized sum; `--no-overlap` reproduces the PR 2
//! serialized pool; a staged tenant with `--stream-weights` beats
//! blocking reprogramming; and overlapped dispatch stays bit-identical
//! across runs under a fixed seed.

use imcc::arch::PowerModel;
use imcc::net::bottleneck::bottleneck;
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::serve::{
    mnv2_bottleneck_pair, simulate, BatchWindow, ModelTraffic, ServeConfig, TrafficModel,
};

/// `n_models` bottleneck tenants, each with `n_requests` arrivals at t=0.
fn t0_fleet(n_models: usize, n_requests: usize) -> Vec<ModelTraffic> {
    (0..n_models)
        .map(|i| {
            let mut net = bottleneck();
            net.name = format!("bn-{i}");
            ModelTraffic {
                net,
                traffic: TrafficModel::Trace {
                    arrivals_cy: vec![0; n_requests],
                },
                weight: 1,
            }
        })
        .collect()
}

#[test]
fn disjoint_tenants_overlap_strictly_below_serialized_sum() {
    // the acceptance scenario: two resident tenants on disjoint slices,
    // one t=0 batch each — the overlapped makespan must be strictly
    // below the serialized sum `--no-overlap` produces
    let pm = PowerModel::paper();
    let base = ServeConfig {
        n_arrays: 16,
        window: BatchWindow {
            max_batch: 8,
            max_wait_cy: 0,
        },
        duration_s: 0.01,
        ..ServeConfig::default()
    };
    let models = t0_fleet(2, 8);
    let on = simulate(&models, &base, &pm).unwrap();
    let off = simulate(
        &models,
        &ServeConfig {
            overlap: false,
            ..base
        },
        &pm,
    )
    .unwrap();

    // both tenants resident in disjoint slices, same work either way
    assert!(on.tenants.iter().all(|t| t.n_passes == 1));
    assert_eq!(on.total_served(), 16);
    assert_eq!(off.total_served(), 16);
    for (a, b) in on.tenants.iter().zip(off.tenants.iter()) {
        assert_eq!(a.batches, b.batches, "{}", a.name);
        assert_eq!(a.busy_cycles, b.busy_cycles, "{}", a.name);
    }

    // serialized mode is back-to-back: makespan = sum of batch makespans
    let sum: u64 = off.tenants.iter().map(|t| t.busy_cycles).sum();
    assert_eq!(off.makespan_cycles, sum, "serialized pool must not overlap");

    // the headline: overlap strictly beats the serialized sum
    assert!(
        on.makespan_cycles < off.makespan_cycles,
        "{} !< {}",
        on.makespan_cycles,
        off.makespan_cycles
    );
    // but never beats the slowest single batch
    let slowest = on.tenants.iter().map(|t| t.busy_cycles).max().unwrap();
    assert!(on.makespan_cycles >= slowest);
    // and the pool-busy union stays inside the makespan
    assert!(on.busy_cycles <= on.makespan_cycles);
}

#[test]
fn no_overlap_is_the_serialized_pr2_pool() {
    // under the default seed, `--no-overlap` keeps one batch in flight:
    // the pool-busy union equals the plain sum of dispatched batch
    // makespans, and the run is bit-identical across repeats
    let pm = PowerModel::paper();
    let scfg = ServeConfig {
        overlap: false,
        duration_s: 0.05,
        ..ServeConfig::default()
    };
    let rep = simulate(&mnv2_bottleneck_pair(150.0), &scfg, &pm).unwrap();
    let sum: u64 = rep.tenants.iter().map(|t| t.busy_cycles).sum();
    assert_eq!(rep.busy_cycles, sum, "serialized batches never overlap");
    assert!(rep.utilization() <= 1.0);
    let again = simulate(&mnv2_bottleneck_pair(150.0), &scfg, &pm).unwrap();
    assert_eq!(rep.render_table(), again.render_table());
    assert_eq!(rep.makespan_cycles, again.makespan_cycles);
}

#[test]
fn overlapped_tables_are_bit_identical_under_a_seed() {
    let pm = PowerModel::paper();
    let scfg = ServeConfig {
        seed: 0x0DD5_EED5,
        duration_s: 0.1,
        ..ServeConfig::default()
    };
    let a = simulate(&mnv2_bottleneck_pair(200.0), &scfg, &pm).unwrap();
    let b = simulate(&mnv2_bottleneck_pair(200.0), &scfg, &pm).unwrap();
    assert!(a.overlap, "default dispatch is overlapped");
    assert_eq!(a.render_table(), b.render_table());
    assert_eq!(a.makespan_cycles, b.makespan_cycles);
    assert_eq!(a.busy_cycles, b.busy_cycles);
    for (x, y) in a.tenants.iter().zip(b.tenants.iter()) {
        assert_eq!(x.latency.percentiles(), y.latency.percentiles());
        assert_eq!((x.served, x.batches, x.dropped), (y.served, y.batches, y.dropped));
    }
}

#[test]
fn streamed_weights_beat_blocking_reprogramming_when_staged() {
    // the acceptance scenario: a staged MobileNetV2 tenant drains the
    // same backlog strictly faster with `--stream-weights`. Pinned under
    // envelope dispatch (the PR 3 discipline this property was proven
    // for): batches serialize on their shared envelopes, so the per-batch
    // strict win carries to the serve makespan — backfilling interleaves
    // same-tenant batches and no longer guarantees strictness per se.
    let pm = PowerModel::paper();
    let models = vec![ModelTraffic {
        net: mobilenet_v2(224),
        traffic: TrafficModel::Trace {
            arrivals_cy: vec![0; 6],
        },
        weight: 1,
    }];
    let base = ServeConfig {
        n_arrays: 8,
        window: BatchWindow {
            max_batch: 2,
            max_wait_cy: 0,
        },
        duration_s: 0.01,
        backfill: false,
        ..ServeConfig::default()
    };
    let block = simulate(&models, &base, &pm).unwrap();
    let stream = simulate(
        &models,
        &ServeConfig {
            stream_weights: true,
            ..base
        },
        &pm,
    )
    .unwrap();
    assert!(block.tenants[0].n_passes > 1, "8 arrays must stage MNv2");
    assert_eq!(stream.total_served(), block.total_served());
    assert_eq!(stream.tenants[0].batches, block.tenants[0].batches);
    assert!(
        stream.makespan_cycles < block.makespan_cycles,
        "{} !< {}",
        stream.makespan_cycles,
        block.makespan_cycles
    );
}
