//! Admission-control properties (no artifacts needed).
//!
//! Three contracts of the front-door gate (`--slo-p95`):
//!
//! * **conservation** — every offered arrival is accounted exactly once:
//!   `served + dropped + rejected = arrivals`, per tenant, across random
//!   Poisson and MMPP-2 fleets with deadlines and the autoscaler in every
//!   combination;
//! * **SLO conformance** — on an uncontended slice the drain bound the
//!   predictor admits against is a hard guarantee, so whenever the
//!   *uncontrolled* run blows a p95 budget, the *controlled* run's served
//!   p95 stays within it (the refusals land on `rejected` instead of the
//!   tail);
//! * **off switch** — with the budget unset or `--no-admission`, and
//!   `--no-autoscale`, the dispatch table and the deterministic work
//!   counters are bit-identical to the uncontrolled baseline: the
//!   controllers are strictly additive.

use imcc::arch::PowerModel;
use imcc::serve::{
    bottleneck_fleet, mnv2_bottleneck_pair, simulate, ModelTraffic, ServeConfig, TrafficModel,
};

/// The pair fleet with every tenant's arrival process replaced.
fn with_traffic(mut models: Vec<ModelTraffic>, traffic: &TrafficModel) -> Vec<ModelTraffic> {
    for m in &mut models {
        m.traffic = traffic.clone();
    }
    models
}

#[test]
fn admission_conserves_every_offered_arrival() {
    let pm = PowerModel::paper();
    for seed in [0x11u64, 0xBEEF, 0xC0FF_EE77] {
        for rate in [200.0f64, 900.0] {
            let traffics = [
                TrafficModel::Poisson { rate_per_s: rate },
                TrafficModel::Bursty {
                    rate_per_s: rate,
                    burst: 4.0,
                    dwell_s: 0.005,
                },
            ];
            for traffic in &traffics {
                for autoscale in [false, true] {
                    let scfg = ServeConfig {
                        n_arrays: 64,
                        seed,
                        duration_s: 0.02,
                        deadline_cy: 400_000,
                        slo_p95_cy: 600_000,
                        autoscale,
                        headroom: if autoscale { 8 } else { 0 },
                        ..ServeConfig::default()
                    };
                    let models = with_traffic(mnv2_bottleneck_pair(rate), traffic);
                    let rep = simulate(&models, &scfg, &pm).unwrap();
                    for s in &rep.tenants {
                        assert_eq!(
                            s.served + s.dropped + s.rejected,
                            s.arrivals,
                            "{} seed {seed:#x} rate {rate} autoscale {autoscale}: \
                             {} + {} + {} != {}",
                            s.name,
                            s.served,
                            s.dropped,
                            s.rejected,
                            s.arrivals
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn controlled_p95_meets_the_budget_the_uncontrolled_run_blew() {
    let pm = PowerModel::paper();
    // one bottleneck tenant alone in a small pool: resident, uncontended —
    // the regime where the predictor's drain bound is a hard guarantee
    let rate = 20_000.0;
    let models = bottleneck_fleet(1, rate);
    let base = ServeConfig {
        n_arrays: 8,
        seed: 0xABCD,
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    let unc = simulate(&models, &base, &pm).unwrap();
    let s = &unc.tenants[0];
    assert_eq!(s.served, s.arrivals, "uncontrolled run never sheds");
    let p95_unc = s.latency.quantile(0.95);
    let budget = (p95_unc / 2).max(1);
    assert!(p95_unc > budget, "overload must blow the halved budget");

    let ctrl_cfg = ServeConfig {
        slo_p95_cy: budget,
        ..base.clone()
    };
    let ctrl = simulate(&models, &ctrl_cfg, &pm).unwrap();
    let c = &ctrl.tenants[0];
    assert_eq!(c.served + c.rejected, c.arrivals, "no deadline: only refusals shed");
    assert!(c.rejected > 0, "overload under a halved budget must refuse something");
    let p95_ctrl = c.latency.quantile(0.95);
    assert!(
        p95_ctrl <= budget,
        "served p95 {p95_ctrl} blows the admitted budget {budget} (uncontrolled {p95_unc})"
    );
}

#[test]
fn budget_off_switch_is_bit_identical_to_the_uncontrolled_baseline() {
    let pm = PowerModel::paper();
    for seed in [0x5EED_u64, 0xFACE] {
        for backfill in [true, false] {
            for rate in [150.0f64, 600.0] {
                let models = mnv2_bottleneck_pair(rate);
                let base_cfg = ServeConfig {
                    n_arrays: 64,
                    seed,
                    backfill,
                    duration_s: 0.02,
                    deadline_cy: 2_000_000,
                    ..ServeConfig::default()
                };
                let base = simulate(&models, &base_cfg, &pm).unwrap();
                assert_eq!(base.total_rejected(), 0);
                assert!(base.scale_events.is_empty());

                // budget set but the master switch off (--no-admission
                // --no-autoscale): the run must take exactly the
                // uncontrolled code paths
                let off_cfg = ServeConfig {
                    slo_p95_cy: 5_000_000,
                    admission: false,
                    autoscale: false,
                    ..base_cfg.clone()
                };
                let off = simulate(&models, &off_cfg, &pm).unwrap();
                assert_eq!(
                    off.render_table(),
                    base.render_table(),
                    "seed {seed:#x} backfill {backfill} rate {rate}"
                );
                assert_eq!(off.counters, base.counters);
                assert_eq!(off.makespan_cycles, base.makespan_cycles);
                assert!(off.scale_events.is_empty());
                assert!(!off.admission, "budget echo without the gate");

                // budget unset with the switch on is the same baseline too
                let unset_cfg = ServeConfig {
                    slo_p95_cy: 0,
                    admission: true,
                    ..base_cfg.clone()
                };
                let unset = simulate(&models, &unset_cfg, &pm).unwrap();
                assert_eq!(unset.render_table(), base.render_table());
                assert_eq!(unset.counters, base.counters);
            }
        }
    }
}
