//! Serving-subsystem regression tests (no artifacts needed).
//!
//! Pins the contract points of the event-driven multi-model simulator:
//! seeded-trace determinism (the percentile table is bit-identical under a
//! fixed seed), strict-mode equivalence (one model through a 1-wide window
//! with `overlap: false` equals the scheduler's sequential baseline
//! exactly — the PR 2 serialized pool), and arbitration
//! fairness/starvation properties under two tenants (run serialized,
//! where the arbiter fully decides the order). Overlapped-dispatch
//! regressions live in `tests/overlap_regression.rs`.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::{run_batched, BatchConfig, PlanCache, Strategy};
use imcc::net::bottleneck::bottleneck;
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::serve::{
    mnv2_bottleneck_pair as poisson_pair, simulate, BatchWindow, ModelTraffic, Policy,
    ServeConfig, TrafficModel,
};
use imcc::util::prop;
use imcc::util::rng::SplitMix64;

#[test]
fn seeded_percentile_tables_are_bit_identical() {
    // the acceptance scenario: two models resident in one pool under a
    // seeded Poisson trace; the printed table must be identical across
    // runs with the same seed and differ across seeds
    let pm = PowerModel::paper();
    let scfg = ServeConfig {
        seed: 0xDEAD_BEEF,
        duration_s: 0.1,
        ..ServeConfig::default()
    };
    let a = simulate(&poisson_pair(150.0), &scfg, &pm).unwrap();
    let b = simulate(&poisson_pair(150.0), &scfg, &pm).unwrap();
    assert_eq!(a.render_table(), b.render_table());
    assert_eq!(a.makespan_cycles, b.makespan_cycles);
    assert_eq!(a.busy_cycles, b.busy_cycles);
    for (x, y) in a.tenants.iter().zip(b.tenants.iter()) {
        assert_eq!(x.latency.percentiles(), y.latency.percentiles());
        assert_eq!((x.served, x.batches, x.dropped), (y.served, y.batches, y.dropped));
    }
    // both tenants really are resident together (multi-model residency)
    assert!(a.tenants.iter().all(|t| t.n_passes == 1));
    assert!(a.tenants.iter().all(|t| t.served > 0));

    let other = ServeConfig {
        seed: 0xFACE_FEED,
        ..scfg.clone()
    };
    let c = simulate(&poisson_pair(150.0), &other, &pm).unwrap();
    // different seeds → different arrival times; the exact makespan (or
    // failing that, the quantized table) must move
    assert!(
        a.makespan_cycles != c.makespan_cycles || a.render_table() != c.render_table(),
        "different seeds must yield different traffic"
    );
}

#[test]
fn strict_window_equals_sequential_baseline_resident() {
    // one model, 1-wide window, pipelining off, all arrivals at t=0: the
    // serving loop degenerates to N back-to-back sequential runs
    let pm = PowerModel::paper();
    let n = 5usize;
    let models = vec![ModelTraffic {
        net: bottleneck(),
        traffic: TrafficModel::Trace {
            arrivals_cy: vec![0; n],
        },
        weight: 1,
    }];
    let scfg = ServeConfig {
        n_arrays: 8,
        window: BatchWindow {
            max_batch: 1,
            max_wait_cy: 0,
        },
        pipeline: false,
        overlap: false,
        duration_s: 0.01,
        ..ServeConfig::default()
    };
    let rep = simulate(&models, &scfg, &pm).unwrap();
    assert_eq!(rep.tenants[0].served, n as u64);
    assert_eq!(rep.tenants[0].batches, n as u64);

    let cfg = SystemConfig::scaled_up(8);
    let mut cache = PlanCache::new();
    let plan = cache.get_or_place(&bottleneck(), 256, 8, false).unwrap();
    let strict = run_batched(
        &bottleneck(),
        Strategy::ImaDw,
        &cfg,
        &pm,
        &plan,
        BatchConfig {
            batch: n,
            pipeline: false,
            ..BatchConfig::default()
        },
    );
    assert_eq!(rep.makespan_cycles, strict.cycles, "served totals must be bit-identical");
    assert_eq!(rep.makespan_cycles, strict.sequential_cycles);
    assert_eq!(rep.busy_cycles, strict.cycles, "no idle gaps with a t=0 backlog");
}

#[test]
fn strict_window_equals_sequential_baseline_staged() {
    // same property on a staged (undersized-pool) tenant: every
    // single-request batch pays its own reprogramming and boundary DMA,
    // exactly like the scheduler's honest sequential baseline
    let pm = PowerModel::paper();
    let n = 3usize;
    let models = vec![ModelTraffic {
        net: mobilenet_v2(224),
        traffic: TrafficModel::Trace {
            arrivals_cy: vec![0; n],
        },
        weight: 1,
    }];
    let scfg = ServeConfig {
        n_arrays: 8,
        window: BatchWindow {
            max_batch: 1,
            max_wait_cy: 0,
        },
        pipeline: false,
        overlap: false,
        duration_s: 0.01,
        ..ServeConfig::default()
    };
    let rep = simulate(&models, &scfg, &pm).unwrap();
    assert!(rep.tenants[0].n_passes > 1, "8 arrays must stage MNv2");
    assert_eq!(rep.tenants[0].served, n as u64);

    let cfg = SystemConfig::scaled_up(8);
    let mut cache = PlanCache::new();
    let plan = cache.get_or_place(&mobilenet_v2(224), 256, 8, false).unwrap();
    let strict = run_batched(
        &mobilenet_v2(224),
        Strategy::ImaDw,
        &cfg,
        &pm,
        &plan,
        BatchConfig {
            batch: n,
            pipeline: false,
            ..BatchConfig::default()
        },
    );
    // batch-major strict serving amortizes reprogramming, one-at-a-time
    // serving cannot: the serve totals match the *sequential* baseline
    assert_eq!(rep.makespan_cycles, strict.sequential_cycles);
    assert!(rep.makespan_cycles > strict.cycles);
}

#[test]
fn wrr_equal_weights_alternate_batches_under_backlog() {
    // fairness property: two tenants with identical t=0 backlogs and
    // equal weights drain in strict alternation — identical batch counts,
    // every request of both served, and the tenant served first in each
    // round finishes strictly earlier on average
    prop::check("wrr_fairness", 24, |rng: &mut SplitMix64| {
        let pm = PowerModel::paper();
        let n = rng.range_i64(4, 64) as usize;
        let max_batch = rng.range_i64(1, 8) as usize;
        let mk = |name: &str| {
            let mut net = bottleneck();
            net.name = name.into();
            ModelTraffic {
                net,
                traffic: TrafficModel::Trace {
                    arrivals_cy: vec![0; n],
                },
                weight: 1,
            }
        };
        let models = vec![mk("bn-a"), mk("bn-b")];
        let scfg = ServeConfig {
            n_arrays: 16,
            policy: Policy::Wrr,
            window: BatchWindow {
                max_batch,
                max_wait_cy: 50_000,
            },
            overlap: false, // serialized: the arbiter fully orders batches
            duration_s: 0.05,
            ..ServeConfig::default()
        };
        let rep = simulate(&models, &scfg, &pm).unwrap();
        let (a, b) = (&rep.tenants[0], &rep.tenants[1]);
        assert_eq!(a.served, n as u64);
        assert_eq!(b.served, n as u64);
        assert_eq!(
            a.batches, b.batches,
            "equal backlogs, equal weights (n {n}, max_batch {max_batch})"
        );
        assert!(
            a.latency.mean() < b.latency.mean(),
            "round-robin serves tenant 0 first in every round"
        );
    });
}

#[test]
fn wrr_weights_bias_latency_toward_the_heavier_tenant() {
    // weight 3 vs 1 on identical backlogs: the heavier tenant's requests
    // finish earlier on average
    let pm = PowerModel::paper();
    let n = 64usize;
    let mk = |name: &str, weight: u64| {
        let mut net = bottleneck();
        net.name = name.into();
        ModelTraffic {
            net,
            traffic: TrafficModel::Trace {
                arrivals_cy: vec![0; n],
            },
            weight,
        }
    };
    let models = vec![mk("heavy", 3), mk("light", 1)];
    let scfg = ServeConfig {
        n_arrays: 16,
        policy: Policy::Wrr,
        overlap: false, // serialized: the arbiter fully orders batches
        duration_s: 0.05,
        ..ServeConfig::default()
    };
    let rep = simulate(&models, &scfg, &pm).unwrap();
    let (h, l) = (&rep.tenants[0], &rep.tenants[1]);
    assert_eq!(h.served, n as u64);
    assert_eq!(l.served, n as u64);
    assert!(
        h.latency.mean() < l.latency.mean(),
        "{} vs {}",
        h.latency.mean(),
        l.latency.mean()
    );
}

#[test]
fn sjf_shields_the_light_model_fifo_couples_them() {
    // classic arbitration result under overload: SJF keeps the cheap
    // model's latency near its service time by always jumping it ahead of
    // the heavy model's queue; FIFO makes it wait in the shared backlog
    let pm = PowerModel::paper();
    let run = |policy: Policy| {
        let scfg = ServeConfig {
            policy,
            seed: 0xBEEF,
            overlap: false, // serialized: policy fully decides the order
            duration_s: 0.05,
            ..ServeConfig::default()
        };
        simulate(&poisson_pair(600.0), &scfg, &pm).unwrap()
    };
    let sjf = run(Policy::Sjf);
    let fifo = run(Policy::Fifo);
    let bn_p50 = |r: &imcc::serve::ServeReport| r.tenants[1].latency.quantile(0.5);
    let mnv2_p50 = |r: &imcc::serve::ServeReport| r.tenants[0].latency.quantile(0.5);
    assert!(
        (bn_p50(&sjf) as f64) * 1.5 < bn_p50(&fifo) as f64,
        "sjf {} vs fifo {}",
        bn_p50(&sjf),
        bn_p50(&fifo)
    );
    // and under SJF the light model is far faster than the starved heavy one
    assert!(bn_p50(&sjf) * 3 < mnv2_p50(&sjf));
}
