//! Autoscaler regression pins (no artifacts needed).
//!
//! Seeded decision-trace pins for the online pool-resizing controller:
//!
//! * a load step triggers **exactly one grow**, only after the hysteresis
//!   window of sustained evidence, and the trace replays bit-identically
//!   under the same configuration (and moves when the seed does);
//! * the migration price charged on the event is **exactly** the PCM
//!   reprogramming of the arrays the re-planned slice touches —
//!   `ImaArrayPool::program_cycles_by_array` of the new plan's first pass,
//!   recomputed here independently;
//! * a shrink **returns arrays a co-tenant's grow then claims**: the
//!   grown slice starts exactly where the shrunken one now ends;
//! * a **streamed** migration (`--stream-weights`) never floors the
//!   tenant's dispatches and the drain finishes strictly earlier than
//!   with a blocking migration (pinned under serialized dispatch, where
//!   the per-batch strict win provably carries to the makespan).
//!
//! The expected slice geometry is recomputed from the same pure placement
//! functions the simulator uses (`PlanCache::get_or_place` is a pure
//! function of the geometry key), so these pins survive cost-model tuning
//! — they break only when the controller's decisions change.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::PlanCache;
use imcc::ima::ImaArrayPool;
use imcc::net::bottleneck::bottleneck;
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::net::Network;
use imcc::serve::{
    simulate, AutoscaleConfig, ModelTraffic, ScaleKind, ServeConfig, TrafficModel,
};
use imcc::tilepack::StagedPlacement;

/// Arrays the slice actually spans — the max over passes, exactly what
/// `place_tenants` carves and the autoscaler reserves.
fn max_used(plan: &StagedPlacement) -> usize {
    plan.passes.iter().map(|p| p.arrays_used).max().unwrap_or(0)
}

/// The controller's grow step for a tenant holding `cur` arrays.
fn grow_target(cur: usize) -> usize {
    cur + (cur / 2).max(1)
}

fn trace_tenant(net: Network, arrivals_cy: Vec<u64>) -> ModelTraffic {
    ModelTraffic {
        net,
        traffic: TrafficModel::Trace { arrivals_cy },
        weight: 1,
    }
}

/// One-scale-only controller config: default hysteresis, but a cooldown no
/// run outlives — each tenant scales at most once.
fn one_shot_cfg() -> AutoscaleConfig {
    AutoscaleConfig {
        cooldown_cy: u64::MAX / 2,
        ..AutoscaleConfig::default()
    }
}

#[test]
fn load_step_triggers_exactly_one_grow_after_the_window() {
    let pm = PowerModel::paper();
    let n_arrays = 40usize;
    let headroom = 32usize; // carve 8: MobileNetV2 starts staged
    let acfg = one_shot_cfg();

    // recompute the expected geometry from the same pure placement
    let mut cache = PlanCache::new();
    let net = mobilenet_v2(224);
    let init = max_used(&cache.get_or_place(&net, 256, n_arrays - headroom, false).unwrap());
    let target = grow_target(init);
    let grown = cache.get_or_place(&net, 256, target, false).unwrap();
    let used_t = max_used(&grown);
    assert!(
        used_t > init,
        "precondition: the grow step must spread the staged plan ({init} -> {used_t})"
    );

    let models = vec![trace_tenant(mobilenet_v2(224), vec![0; 120])];
    let scfg = ServeConfig {
        n_arrays,
        headroom,
        autoscale: true,
        autoscale_cfg: acfg,
        duration_s: 0.01,
        ..ServeConfig::default()
    };
    let rep = simulate(&models, &scfg, &pm).unwrap();
    assert_eq!(rep.scale_events.len(), 1, "one load step, one grow");
    let ev = rep.scale_events[0];
    assert_eq!(ev.kind, ScaleKind::Grow);
    assert_eq!(ev.tenant, 0);
    assert_eq!((ev.from_base, ev.from_arrays), (0, init));
    assert_eq!((ev.to_base, ev.to_arrays), (0, used_t));
    assert!(
        ev.t >= acfg.window_cy,
        "grow at {} fired before the {}-cycle hysteresis window",
        ev.t,
        acfg.window_cy
    );
    assert!(!ev.streamed);
    // blocking migration: the dispatch floor covers at least the whole
    // serialized reprogramming chain
    assert!(ev.blocked_cycles >= ev.program_cycles);
    assert!(rep.tenants[0].arrays == used_t, "stats echo the new slice");
    assert_eq!(rep.total_served(), 120, "the drain completes after the resize");

    // migration price: exactly the PCM reprogramming of the arrays the
    // new plan's first pass touches, recomputed independently
    let cfg = SystemConfig::scaled_up(n_arrays);
    let pool = ImaArrayPool::new(&cfg, &pm);
    let expected: u64 = pool.program_cycles_by_array(&grown.passes[0]).values().sum();
    assert!(expected > 0);
    assert_eq!(ev.program_cycles, expected);

    // bit-identical replay under the same configuration
    let again = simulate(&models, &scfg, &pm).unwrap();
    assert_eq!(format!("{:?}", again.scale_events), format!("{:?}", rep.scale_events));
    assert_eq!(again.render_table(), rep.render_table());
}

#[test]
fn decision_trace_replays_under_a_seed_and_moves_with_it() {
    let pm = PowerModel::paper();
    let models = vec![ModelTraffic {
        net: mobilenet_v2(224),
        traffic: TrafficModel::Poisson { rate_per_s: 5_000.0 },
        weight: 1,
    }];
    let mk = |seed: u64| ServeConfig {
        n_arrays: 40,
        headroom: 32,
        autoscale: true,
        autoscale_cfg: one_shot_cfg(),
        seed,
        duration_s: 0.01,
        ..ServeConfig::default()
    };
    let a = simulate(&models, &mk(0xA11CE), &pm).unwrap();
    let b = simulate(&models, &mk(0xA11CE), &pm).unwrap();
    assert_eq!(format!("{:?}", a.scale_events), format!("{:?}", b.scale_events));
    assert_eq!(a.render_table(), b.render_table());
    assert_eq!(a.makespan_cycles, b.makespan_cycles);

    let c = simulate(&models, &mk(0xB0B), &pm).unwrap();
    let moved = format!("{:?}", a.scale_events) != format!("{:?}", c.scale_events)
        || a.tenants[0].arrivals != c.tenants[0].arrivals
        || a.makespan_cycles != c.makespan_cycles;
    assert!(moved, "a different seed must move the trace or the arrivals");
}

#[test]
fn shrink_returns_arrays_a_cotenants_grow_claims() {
    let pm = PowerModel::paper();
    let acfg = AutoscaleConfig::default();
    let w = acfg.window_cy;

    // tenant A: resident bottleneck, one request at t=0, idle forever
    // after — sustained-low once its old depth sample ages out. Tenant B:
    // staged MobileNetV2 bursting at 3·window — sustained-high during the
    // drain. Both become eligible at the same event step ≥ burst + window;
    // the controller pass runs in tenant order, so A's shrink frees its
    // tail first and B's grow (which could not fit before: no free run
    // wider than its own slice) claims the returned arrays.
    let mut cache = PlanCache::new();
    let net_a = bottleneck();
    let net_b = mobilenet_v2(224);
    let resident = max_used(&cache.get_or_place(&net_a, 256, 64, false).unwrap());
    assert!(resident >= 2, "shrink needs at least 2 arrays to halve");
    // the co-tenant must fill its carve exactly (else the pool keeps a
    // free tail and the grow never waits for the shrink) and must be
    // staged, so a wider run genuinely spreads its plan — search for the
    // smallest such carve instead of hard-coding packer geometry
    let b_carve = (4..=12)
        .find(|&k| max_used(&cache.get_or_place(&net_b, 256, k, false).unwrap()) == k)
        .expect("no carve in 4..=12 that MobileNetV2 fills exactly");
    let b_init = b_carve;
    let n_arrays = resident + b_carve;

    // A's shrink geometry
    let a_target = resident - (resident / 2).max(1);
    let a_new = max_used(&cache.get_or_place(&net_a, 256, a_target, false).unwrap());
    assert!(a_new < resident, "precondition: the shrink must return arrays");
    // B's grow geometry after the return: the coalesced run starts at A's
    // new end and spans everything to the pool edge
    let run_len = n_arrays - a_new;
    let b_trial = run_len.min(grow_target(b_init));
    assert!(run_len >= b_init + 1, "the returned tail must widen B's run");
    let b_new = max_used(&cache.get_or_place(&net_b, 256, b_trial, false).unwrap());
    assert!(
        b_new > b_init,
        "precondition: the claimed run must spread B's plan ({b_init} -> {b_new})"
    );

    let burst_t = 3 * w;
    let models = vec![
        trace_tenant(net_a, vec![0]),
        trace_tenant(net_b, vec![burst_t; 300]),
    ];
    let scfg = ServeConfig {
        n_arrays,
        autoscale: true,
        autoscale_cfg: one_shot_cfg(),
        duration_s: 0.05,
        ..ServeConfig::default()
    };
    let rep = simulate(&models, &scfg, &pm).unwrap();
    assert_eq!(
        rep.scale_events.len(),
        2,
        "one shrink + one grow: {:?}",
        rep.scale_events
    );
    let shrink = rep.scale_events[0];
    let grow = rep.scale_events[1];
    assert_eq!((shrink.kind, shrink.tenant), (ScaleKind::Shrink, 0));
    assert_eq!((grow.kind, grow.tenant), (ScaleKind::Grow, 1));
    assert_eq!((shrink.from_base, shrink.from_arrays), (0, resident));
    assert_eq!((shrink.to_base, shrink.to_arrays), (0, a_new));
    assert_eq!((grow.from_base, grow.from_arrays), (resident, b_init));
    // the claim: B's grown slice starts exactly where A's shrunken slice
    // now ends — the returned arrays are what made the run wide enough
    assert_eq!(grow.to_base, shrink.to_base + shrink.to_arrays);
    assert_eq!(grow.to_arrays, b_new);
    assert!(shrink.t >= burst_t + w, "eligibility needs post-burst coverage");
    assert!(grow.t >= shrink.t, "the shrink frees the run the grow claims");
}

#[test]
fn streamed_migration_never_floors_and_beats_blocking() {
    let pm = PowerModel::paper();
    // serialized dispatch: the single-server clock ignores the timeline,
    // so a blocking migration's floor is the *only* coupling — and the
    // per-batch streamed-reprogramming win provably carries to the
    // makespan (see overlap_regression for the batch-level pin)
    let base = ServeConfig {
        n_arrays: 40,
        headroom: 32,
        autoscale: true,
        autoscale_cfg: one_shot_cfg(),
        overlap: false,
        backfill: false,
        duration_s: 0.01,
        ..ServeConfig::default()
    };
    let models = vec![trace_tenant(mobilenet_v2(224), vec![0; 120])];
    let block = simulate(&models, &base, &pm).unwrap();
    let stream = simulate(
        &models,
        &ServeConfig {
            stream_weights: true,
            ..base
        },
        &pm,
    )
    .unwrap();
    assert_eq!(block.scale_events.len(), 1);
    assert_eq!(stream.scale_events.len(), 1);
    let bev = block.scale_events[0];
    let sev = stream.scale_events[0];
    // same slice move, same migration price — only the charging differs
    assert_eq!((bev.from_arrays, bev.to_arrays), (sev.from_arrays, sev.to_arrays));
    assert_eq!(bev.program_cycles, sev.program_cycles);
    assert!(bev.program_cycles > 0);
    assert!(!bev.streamed && bev.blocked_cycles >= bev.program_cycles);
    assert!(sev.streamed && sev.blocked_cycles == 0);
    assert_eq!(stream.total_served(), block.total_served());
    assert!(
        stream.makespan_cycles < block.makespan_cycles,
        "{} !< {}",
        stream.makespan_cycles,
        block.makespan_cycles
    );
}
