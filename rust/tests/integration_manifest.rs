//! Cross-checks between the Python-serialized manifest (netspec.py) and the
//! independent Rust network builder (net::mobilenetv2) — the two sources of
//! truth must never drift.

use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::net::LayerKind;
use imcc::runtime::Manifest;

fn artifacts_dir() -> String {
    std::env::var("IMCC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// These tests read Python build products; on a clean checkout (no `make
/// artifacts`) they skip cleanly so `cargo test -q` stays green.
fn have_manifest(tiny: bool) -> bool {
    let name = if tiny { "manifest_tiny.json" } else { "manifest.json" };
    let path = format!("{}/{name}", artifacts_dir());
    if std::path::Path::new(&path).exists() {
        true
    } else {
        eprintln!("skipping manifest test: `{path}` not found (run `make artifacts`)");
        false
    }
}

#[test]
fn manifest_network_matches_rust_builder_layer_by_layer() {
    if !have_manifest(false) {
        return;
    }
    let m = Manifest::load(&artifacts_dir(), false).unwrap();
    let ours = mobilenet_v2(224);
    let theirs = m.to_network();
    assert_eq!(ours.layers.len(), theirs.layers.len());
    for (a, b) in ours.layers.iter().zip(theirs.layers.iter()) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.kind, b.kind, "{}", a.name);
        assert_eq!(
            (a.hin, a.win, a.cin, a.cout, a.k, a.stride, a.pad, a.relu),
            (b.hin, b.win, b.cin, b.cout, b.k, b.stride, b.pad, b.relu),
            "{}",
            a.name
        );
        assert_eq!(a.residual_from, b.residual_from, "{}", a.name);
        assert_eq!(a.macs(), b.macs(), "{}", a.name);
    }
    assert_eq!(ours.total_macs(), theirs.total_macs());
}

#[test]
fn manifest_weights_cover_every_parametric_layer() {
    if !have_manifest(false) {
        return;
    }
    let m = Manifest::load(&artifacts_dir(), false).unwrap();
    let mut covered = 0usize;
    for (i, ml) in m.layers.iter().enumerate() {
        match ml.layer.kind {
            LayerKind::Conv | LayerKind::Fc => {
                assert_eq!(
                    ml.weight_len,
                    ml.layer.k * ml.layer.k * ml.layer.cin * ml.layer.cout,
                    "{}",
                    ml.layer.name
                );
                covered += ml.weight_len;
                // weights are int4
                assert!(m.layer_weights(i).iter().all(|w| (-8..=7).contains(w)));
            }
            LayerKind::Dw => {
                assert_eq!(ml.weight_len, 9 * ml.layer.cin);
                covered += ml.weight_len;
            }
            _ => assert_eq!(ml.weight_len, 0),
        }
    }
    assert_eq!(covered, m.weights.len());
}

#[test]
fn manifest_shifts_are_sane() {
    if !have_manifest(false) {
        return;
    }
    let m = Manifest::load(&artifacts_dir(), false).unwrap();
    for ml in &m.layers {
        assert!((0..=24).contains(&ml.layer.shift), "{}", ml.layer.name);
    }
    // input shape is the canonical 224×224×3
    assert_eq!(m.input_shape, (224, 224, 3));
    assert_eq!(m.golden_logits.len(), 1000);
}

#[test]
fn tiny_manifest_loads_too() {
    if !have_manifest(true) {
        return;
    }
    let m = Manifest::load(&artifacts_dir(), true).unwrap();
    assert_eq!(m.network_name, "tiny");
    assert!(m.layers.len() >= 10);
    m.to_network().validate().unwrap();
}
