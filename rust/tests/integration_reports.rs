//! Whole-report integration: every paper exhibit generates, and the headline
//! claims hold in shape (who wins, by roughly what factor).

use imcc::arch::{PowerModel, SystemConfig};
use imcc::report;

#[test]
fn all_reports_generate_and_agree() {
    let cfg = SystemConfig::paper();
    let pm = PowerModel::paper();

    let fig6 = report::fig6_area::generate(&cfg);
    assert!((fig6.data.req("total_mm2").as_f64().unwrap() - 2.5).abs() < 0.01);

    let fig7 = report::fig7_roofline::generate();
    let peak = fig7.data.req("peak_gops").as_f64().unwrap();
    assert!((900.0..1000.0).contains(&peak));

    let fig9 = report::fig9_bottleneck::generate(&cfg, &pm);
    let rows = fig9.data.as_arr().unwrap();
    let perf = |label: &str| {
        rows.iter()
            .find(|r| r.req("mapping").as_str() == Some(label))
            .unwrap()
            .req("perf_vs_cores")
            .as_f64()
            .unwrap()
    };
    // the paper's ordering
    assert!(perf("IMA+DW") > perf("HYBRID"));
    assert!(perf("HYBRID") > perf("IMA_cjob16"));
    assert!(perf("IMA_cjob16") > perf("IMA_cjob8"));
    assert!(perf("IMA_cjob8") >= 1.0);

    let fig12 = report::fig12_e2e::generate(&pm);
    let t = fig12.data.req("total_time_s").as_f64().unwrap();
    let e = fig12.data.req("total_energy_j").as_f64().unwrap();
    // paper: 10.1 ms / 482 µJ; hold within ±25 %
    assert!((t - 10.1e-3).abs() / 10.1e-3 < 0.25, "{t}");
    assert!((e - 482e-6).abs() / 482e-6 < 0.25, "{e}");

    let t1 = report::table1::generate(&pm);
    assert!(t1.text.contains("This work"));
    assert!(t1.text.contains("n/a")); // the undeployable baselines

    let fig13 = report::fig13_models::generate(&pm);
    assert_eq!(
        fig13.data.req("ima_digacc_deployable"),
        &imcc::util::json::Json::Bool(false)
    );
}

#[test]
fn reports_serialize_to_valid_json() {
    let cfg = SystemConfig::paper();
    let pm = PowerModel::paper();
    for rep in [
        report::fig6_area::generate(&cfg),
        report::fig9_bottleneck::generate(&cfg, &pm),
        report::fig13_models::generate(&pm),
    ] {
        let text = rep.data.to_string_pretty();
        let back = imcc::util::json::Json::parse(&text).unwrap();
        assert_eq!(&back, &rep.data, "{}", rep.title);
    }
}
