//! Regression pins for the timeline perf counters (no artifacts needed).
//!
//! * probe/step counters are *exactly* reproducible under a fixed seed —
//!   the property CI's counter-based gating relies on (wall clock flakes,
//!   counters cannot);
//! * on a long-horizon (10× the default serve duration) multi-tenant run
//!   the pruned dispatch produces a bit-identical table at strictly lower
//!   probe work and live-interval footprint than `--no-prune`;
//! * interning batch reports in a shared plan cache changes nothing:
//!   sweeping the same point through one cache is bit-identical to fresh
//!   private caches.

use imcc::arch::PowerModel;
use imcc::coordinator::PlanCache;
use imcc::serve::{
    bottleneck_fleet, mnv2_bottleneck_pair, simulate, simulate_with_cache, ServeConfig,
};

#[test]
fn counters_are_exactly_reproducible_under_a_fixed_seed() {
    let pm = PowerModel::paper();
    let scfg = ServeConfig {
        seed: 0x00C0_FFEE,
        duration_s: 0.1,
        ..ServeConfig::default()
    };
    let a = simulate(&mnv2_bottleneck_pair(250.0), &scfg, &pm).unwrap();
    let b = simulate(&mnv2_bottleneck_pair(250.0), &scfg, &pm).unwrap();
    assert_eq!(a.counters, b.counters, "counters must be deterministic");
    assert!(a.counters.steps > 0);
    assert!(a.counters.validations >= a.counters.steps);
    assert!(a.counters.probes > 0);
    assert!(a.counters.peak_live_intervals >= a.counters.live_intervals);
    // a different seed moves the traffic and with it the counted work
    let other = simulate(
        &mnv2_bottleneck_pair(250.0),
        &ServeConfig {
            seed: 0xBADC_0DE5,
            ..scfg
        },
        &pm,
    )
    .unwrap();
    assert_ne!(a.counters, other.counters, "seeds must move the counters");
}

#[test]
fn long_horizon_pruned_probe_work_is_strictly_below_unpruned() {
    // 10× the default 0.25 s serve horizon, four tenants — the acceptance
    // scenario: equal makespan (and whole dispatch table), strictly less
    // gap-search work and live state
    let pm = PowerModel::paper();
    let models = bottleneck_fleet(4, 150.0);
    let base = ServeConfig {
        n_arrays: 24,
        duration_s: 2.5,
        ..ServeConfig::default()
    };
    let pruned = simulate(&models, &base, &pm).unwrap();
    let unpruned = simulate(
        &models,
        &ServeConfig {
            prune: false,
            ..base
        },
        &pm,
    )
    .unwrap();
    assert_eq!(pruned.makespan_cycles, unpruned.makespan_cycles);
    assert_eq!(pruned.render_table(), unpruned.render_table());
    assert_eq!(pruned.counters.steps, unpruned.counters.steps);
    assert!(
        pruned.counters.probes < unpruned.counters.probes,
        "probe work {} !< {}",
        pruned.counters.probes,
        unpruned.counters.probes
    );
    assert!(
        pruned.counters.live_intervals < unpruned.counters.live_intervals,
        "live {} !< {}",
        pruned.counters.live_intervals,
        unpruned.counters.live_intervals
    );
    assert!(pruned.counters.pruned_intervals > 0);
}

#[test]
fn shared_cache_interning_is_bit_identical_to_private_caches() {
    let pm = PowerModel::paper();
    let models = mnv2_bottleneck_pair(200.0);
    let scfg = ServeConfig {
        duration_s: 0.05,
        ..ServeConfig::default()
    };
    // one shared cache across repeated runs: placements and batch
    // profiles intern and are reused on the second pass
    let mut shared = PlanCache::with_capacity(32);
    let first = simulate_with_cache(&models, &scfg, &pm, &mut shared).unwrap();
    let warm_batch_hits = shared.batch_hits();
    let second = simulate_with_cache(&models, &scfg, &pm, &mut shared).unwrap();
    assert!(
        shared.batch_hits() > warm_batch_hits,
        "the second run must hit the interned batch reports"
    );
    // a private cache per run (the `simulate` path) must agree exactly
    let private = simulate(&models, &scfg, &pm).unwrap();
    for rep in [&first, &second] {
        assert_eq!(rep.render_table(), private.render_table());
        assert_eq!(rep.makespan_cycles, private.makespan_cycles);
        assert_eq!(rep.busy_cycles, private.busy_cycles);
        assert_eq!(rep.counters, private.counters);
    }
}
