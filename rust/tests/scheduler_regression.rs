//! Scheduler regression tests (no artifacts needed).
//!
//! Pins the three contract points of the multi-array batch engine:
//! batched execution with pipelining disabled is *identical* to B
//! sequential runs (cycles and energy), enabling pipelining strictly
//! helps, and a plan-cache hit returns a bit-identical plan.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::{run_batched, run_network, BatchConfig, PlanCache, Strategy};
use imcc::net::bottleneck::bottleneck;
use imcc::net::mobilenetv2::mobilenet_v2;

fn batch(b: usize, pipeline: bool) -> BatchConfig {
    BatchConfig {
        batch: b,
        pipeline,
        ..BatchConfig::default()
    }
}

#[test]
fn batched_disabled_equals_b_sequential_runs() {
    let cfg = SystemConfig::scaled_up(8);
    let pm = PowerModel::paper();
    let net = bottleneck();
    let mut cache = PlanCache::new();
    let plan = cache.get_or_place(&net, 256, 8, false).unwrap();

    let seq = run_network(&net, Strategy::ImaDw, &cfg, &pm);
    for b in [1usize, 2, 4, 7] {
        let rep = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, batch(b, false));
        assert_eq!(rep.cycles, seq.cycles * b as u64, "batch {b}");
        assert_eq!(rep.per_request_cycles, seq.cycles);
        assert!(
            (rep.energy_j - seq.energy_j * b as f64).abs() < 1e-15,
            "batch {b}: {} vs {}",
            rep.energy_j,
            seq.energy_j * b as f64
        );
        assert_eq!(rep.reprogram_cycles, 0, "resident plan must not reprogram");
    }
}

#[test]
fn pipelined_batch_strictly_fewer_cycles() {
    let cfg = SystemConfig::scaled_up(8);
    let pm = PowerModel::paper();
    let net = bottleneck();
    let mut cache = PlanCache::new();
    let plan = cache.get_or_place(&net, 256, 8, false).unwrap();

    for b in [2usize, 4, 8] {
        let strict = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, batch(b, false));
        let piped = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, batch(b, true));
        assert!(
            piped.cycles < strict.cycles,
            "batch {b}: {} !< {}",
            piped.cycles,
            strict.cycles
        );
        // same work, same energy — pipelining moves cycles, not jobs
        assert!((piped.energy_j - strict.energy_j).abs() < 1e-15);
        // schedule sanity: never faster than one request, never slower
        // than strict serving
        assert!(piped.cycles >= piped.per_request_cycles);
        assert!(piped.inferences_per_s() > strict.inferences_per_s());
    }
}

#[test]
fn pipelined_throughput_monotone_in_batch() {
    let cfg = SystemConfig::scaled_up(8);
    let pm = PowerModel::paper();
    let net = bottleneck();
    let mut cache = PlanCache::new();
    let plan = cache.get_or_place(&net, 256, 8, false).unwrap();

    let mut last = 0.0f64;
    for b in [1usize, 2, 4, 8, 16] {
        let rep = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, batch(b, true));
        let inf_s = rep.inferences_per_s();
        assert!(inf_s >= last, "batch {b}: {inf_s} < {last}");
        last = inf_s;
    }
}

#[test]
fn staged_totals_grow_by_modeled_boundary_dma() {
    // satellite: staged passes now charge L2 spill/refill of the
    // cut-boundary activations — totals must grow by exactly the DmaModel
    // cost, per request, per cut
    let cfg = SystemConfig::scaled_up(8);
    let pm = PowerModel::paper();
    let net = mobilenet_v2(224);
    let mut cache = PlanCache::new();
    let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
    assert!(plan.n_passes() > 1, "needs a staged plan");

    let dma = imcc::sim::dma::DmaModel::paper();
    let per_request: u64 = plan
        .pass_ranges
        .windows(2)
        .map(|w| 2 * dma.transfer_cy(net.layers[w[1].0].in_bytes()))
        .sum();
    assert!(per_request > 0);

    for b in [1usize, 3] {
        let charged = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, batch(b, true));
        let uncharged = run_batched(
            &net,
            Strategy::ImaDw,
            &cfg,
            &pm,
            &plan,
            BatchConfig {
                batch: b,
                pipeline: true,
                charge_dma: false,
                ..BatchConfig::default()
            },
        );
        let expected = per_request * b as u64;
        assert_eq!(charged.dma_cycles, expected, "batch {b}");
        assert_eq!(charged.cycles - uncharged.cycles, expected, "batch {b}");
        assert_eq!(uncharged.dma_cycles, 0);
        // the sequential baseline pays the same per-request DMA
        assert_eq!(
            charged.sequential_cycles - uncharged.sequential_cycles,
            expected
        );
    }

    // resident plans never touch L2 on the request path
    let cfg40 = SystemConfig::scaled_up(40);
    let plan40 = cache.get_or_place(&net, 256, 40, false).unwrap();
    let r = run_batched(&net, Strategy::ImaDw, &cfg40, &pm, &plan40, batch(2, true));
    assert_eq!(r.dma_cycles, 0);
}

#[test]
fn plan_cache_hit_returns_bit_identical_plan() {
    let mut cache = PlanCache::new();
    let net = mobilenet_v2(224);
    let miss = cache.get_or_place(&net, 256, 40, false).unwrap();
    assert_eq!((cache.misses(), cache.hits()), (1, 0));
    let hit = cache.get_or_place(&net, 256, 40, false).unwrap();
    assert_eq!((cache.misses(), cache.hits()), (1, 1));
    // same shared object, and bit-identical content
    assert!(std::rc::Rc::ptr_eq(&miss, &hit));
    assert_eq!(*miss, *hit);
    // a freshly computed plan is also identical — placement is a pure
    // function of the geometry key
    let fresh = imcc::tilepack::place_staged(&net, 256, 40, false).unwrap();
    assert_eq!(*miss, fresh);
}

#[test]
fn mnv2_batched_serving_end_to_end() {
    // the acceptance scenario: MobileNetV2, 8-array pool, batch 4 —
    // must complete (staged) and beat batch 1 throughput
    let pm = PowerModel::paper();
    let cfg = SystemConfig::scaled_up(8);
    let net = mobilenet_v2(224);
    let mut cache = PlanCache::new();
    let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
    assert!(plan.n_passes() > 1, "8 arrays cannot hold MNv2 resident");

    let b1 = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, batch(1, true));
    let b4 = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, batch(4, true));
    assert!(b1.reprogram_cycles > 0);
    assert!(b4.inferences_per_s() > b1.inferences_per_s());

    // resident pool: no reprogramming, and pipelining beats batch 1
    let cfg40 = SystemConfig::scaled_up(40);
    let plan40 = cache.get_or_place(&net, 256, 40, false).unwrap();
    let r1 = run_batched(&net, Strategy::ImaDw, &cfg40, &pm, &plan40, batch(1, true));
    let r4 = run_batched(&net, Strategy::ImaDw, &cfg40, &pm, &plan40, batch(4, true));
    assert_eq!(r1.reprogram_cycles, 0);
    assert!(r4.inferences_per_s() > r1.inferences_per_s());
    // resident serving crushes staged serving
    assert!(r4.inferences_per_s() > b4.inferences_per_s());
}
