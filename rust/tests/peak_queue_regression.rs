//! Queue-depth sampling regressions (no artifacts needed).
//!
//! PR 3 sampled backlog only at each tenant's own dispatch-candidate
//! instants. The serving loop now samples every tenant's depth at every
//! event-loop step and aggregates the pool-wide simultaneous backlog
//! (`ServeReport::peak_backlog`) — the quantity per-tenant dispatch
//! sampling cannot see: two tenants whose bursts align stress the pool
//! twice as hard as two tenants whose bursts are disjoint, yet the old
//! per-tenant rows are identical in both cases. These tests pin:
//!
//! * the every-event sample never undercuts the retained PR 3 instrument
//!   (`peak_queue ≥ peak_queue_at_dispatch`) on a bursty MMPP-2 mix;
//! * the pool-wide peak is bracketed by the per-tenant peaks
//!   (`max ≤ peak_backlog ≤ sum`);
//! * exactly-aligned bursts add up (`peak_backlog = sum`) while
//!   provably-disjoint bursts do not (`peak_backlog = max`), with
//!   identical per-tenant rows in both scenarios — the undercount the
//!   old output could never distinguish.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::{run_batched, BatchConfig, PlanCache, Strategy};
use imcc::net::bottleneck::bottleneck;
use imcc::serve::{
    mnv2_bottleneck_pair, simulate, BatchWindow, ModelTraffic, ServeConfig, TrafficModel,
};

#[test]
fn every_event_sampling_never_undercuts_dispatch_sampling_on_mmpp2() {
    let pm = PowerModel::paper();
    let mut models = mnv2_bottleneck_pair(400.0);
    for m in &mut models {
        m.traffic = TrafficModel::Bursty {
            rate_per_s: 400.0,
            burst: 8.0,
            dwell_s: 0.005,
        };
    }
    let scfg = ServeConfig {
        duration_s: 0.05,
        ..ServeConfig::default()
    };
    let rep = simulate(&models, &scfg, &pm).unwrap();
    let mut max_peak = 0usize;
    let mut sum_peak = 0usize;
    for t in &rep.tenants {
        assert_eq!(t.served, t.arrivals, "{}", t.name);
        assert!(
            t.peak_queue >= t.peak_queue_at_dispatch,
            "{}: every-event peak {} < dispatch-instant peak {}",
            t.name,
            t.peak_queue,
            t.peak_queue_at_dispatch
        );
        max_peak = max_peak.max(t.peak_queue);
        sum_peak += t.peak_queue;
    }
    assert!(max_peak > 0, "bursty traffic must queue");
    // the pool-wide simultaneous backlog is bracketed by the per-tenant
    // peaks: it sees at least the busiest tenant (its peak is attained at
    // a sampled event instant when no deadlines drop requests) and never
    // more than all peaks stacked
    assert!(rep.peak_backlog >= max_peak as u64, "{} < {max_peak}", rep.peak_backlog);
    assert!(rep.peak_backlog <= sum_peak as u64, "{} > {sum_peak}", rep.peak_backlog);
}

/// `n` bottleneck tenants whose `n_req` requests all land at the given
/// instants.
fn burst_fleet(arrivals: &[Vec<u64>]) -> Vec<ModelTraffic> {
    arrivals
        .iter()
        .enumerate()
        .map(|(i, arr)| {
            let mut net = bottleneck();
            net.name = format!("bn-{i}");
            ModelTraffic {
                net,
                traffic: TrafficModel::Trace {
                    arrivals_cy: arr.clone(),
                },
                weight: 1,
            }
        })
        .collect()
}

#[test]
fn aligned_bursts_stack_the_pool_backlog_disjoint_bursts_do_not() {
    let pm = PowerModel::paper();
    let n_req = 20usize;
    let n_arrays = 16usize;
    let scfg = ServeConfig {
        n_arrays,
        window: BatchWindow {
            max_batch: 4,
            max_wait_cy: 0,
        },
        duration_s: 0.2,
        ..ServeConfig::default()
    };

    // aligned: both tenants burst at t=0 — the first event-loop step
    // samples both full queues, so the pool peak is the *sum*
    let aligned = simulate(&burst_fleet(&[vec![0; n_req], vec![0; n_req]]), &scfg, &pm).unwrap();
    assert_eq!(aligned.peak_backlog, 2 * n_req as u64);

    // disjoint: tenant B bursts only after tenant A has provably fully
    // drained. Each of A's 5 batches of 4 dispatches no later than the
    // previous batch's completion, so A's drain is bounded by 5× the
    // 4-batch makespan — place B's burst past that bound.
    let cfg = SystemConfig::scaled_up(n_arrays);
    let mut cache = PlanCache::new();
    let plan = cache.get_or_place(&bottleneck(), 256, n_arrays, false).unwrap();
    let rep4 = run_batched(
        &bottleneck(),
        Strategy::ImaDw,
        &cfg,
        &pm,
        &plan,
        BatchConfig {
            batch: 4,
            ..BatchConfig::default()
        },
    );
    let t_late = 5 * rep4.cycles + 10_000;
    let duration_cy = (scfg.duration_s * 1e9 / cfg.freq.cycle_ns()) as u64;
    assert!(t_late < duration_cy, "burst must land inside the horizon");
    let disjoint =
        simulate(&burst_fleet(&[vec![0; n_req], vec![t_late; n_req]]), &scfg, &pm).unwrap();
    assert_eq!(disjoint.peak_backlog, n_req as u64);

    // the per-tenant rows — all the PR 3 output had — are identical in
    // the two scenarios: dispatch-instant sampling undercounts the
    // aligned pool stress by exactly 2×
    for (a, d) in aligned.tenants.iter().zip(disjoint.tenants.iter()) {
        assert_eq!(a.served, n_req as u64);
        assert_eq!(d.served, n_req as u64);
        assert_eq!(a.peak_queue, n_req);
        assert_eq!(d.peak_queue, n_req);
        assert_eq!(a.peak_queue_at_dispatch, d.peak_queue_at_dispatch);
    }
    assert!(aligned.peak_backlog > disjoint.peak_backlog);
}
