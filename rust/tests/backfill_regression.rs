//! Backfilling regression tests (no artifacts needed).
//!
//! Pins the acceptance criteria of the interval-timeline scheduler:
//!
//! * `--no-backfill` (envelope mode) is bit-identical to the PR 3
//!   scalar next-free-time arbiter — checked mechanically against a
//!   verbatim reimplementation of the PR 3 timeline (fused core complex,
//!   one envelope per resource) over real scheduler profiles;
//! * a concrete two-tenant scenario where backfilling is strictly
//!   faster than envelope reservation, with the exact makespans derived
//!   from the profiles themselves;
//! * a concrete two-tenant scenario where the per-core split plus
//!   core-affinity rotation lets small parallel sections of different
//!   tenants share the complex — again exactly;
//! * seeded determinism of the backfilled serve table, and the
//!   backfilled ≤ envelope conservation on the canonical Poisson mix.

use std::collections::BTreeMap;

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::timeline::{
    ResMap, ReservationProfile, ResourceTimeline, N_CORES, RES_ARRAY0, RES_CORE0,
};
use imcc::coordinator::{run_batched, BatchConfig, BatchReport, PlanCache, Strategy};
use imcc::net::bottleneck::bottleneck;
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::net::{Layer, Network};
use imcc::serve::{
    mnv2_bottleneck_pair, place_tenants, simulate, BatchWindow, ModelTraffic, ServeConfig,
    TrafficModel,
};
use imcc::util::rng::SplitMix64;

/// The PR 3 arbiter, reimplemented verbatim as a reference: one scalar
/// next-free time per resource, the core complex fused into a single
/// resource. Core 0 carries the whole-complex span (every core layer
/// engages core 0 and dominates the others — `tests/prop_overlap.rs`
/// pins that), so fusing means listening to core 0 only.
#[derive(Default)]
struct Pr3Timeline {
    free: BTreeMap<usize, u64>,
}

impl Pr3Timeline {
    fn fuse(res: usize, array_base: usize) -> Option<usize> {
        if res < N_CORES {
            if res == RES_CORE0 {
                Some(RES_CORE0)
            } else {
                None // dominated by the fused-complex (core 0) span
            }
        } else if res >= RES_ARRAY0 {
            Some(res + array_base)
        } else {
            Some(res)
        }
    }

    fn earliest_start(&self, prof: &ReservationProfile, array_base: usize, nb: u64) -> u64 {
        let mut t = nb;
        for s in &prof.spans {
            let Some(r) = Self::fuse(s.res, array_base) else {
                continue;
            };
            let free = *self.free.get(&r).unwrap_or(&0);
            t = t.max(free.saturating_sub(s.first_use));
        }
        t
    }

    fn commit(&mut self, t: u64, prof: &ReservationProfile, array_base: usize) {
        for s in &prof.spans {
            let Some(r) = Self::fuse(s.res, array_base) else {
                continue;
            };
            let e = self.free.entry(r).or_insert(0);
            *e = (*e).max(t + s.last_release);
        }
    }
}

/// Real scheduler profiles over resident and staged plans, several batch
/// sizes and schedule flavors.
fn profile_zoo() -> Vec<ReservationProfile> {
    let cfg = SystemConfig::scaled_up(8);
    let pm = PowerModel::paper();
    let mut cache = PlanCache::new();
    let mut out = Vec::new();
    for net in [bottleneck(), mobilenet_v2(224)] {
        let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
        for batch in [1usize, 3] {
            for stream_weights in [false, true] {
                let rep = run_batched(
                    &net,
                    Strategy::ImaDw,
                    &cfg,
                    &pm,
                    &plan,
                    BatchConfig {
                        batch,
                        stream_weights,
                        ..BatchConfig::default()
                    },
                );
                out.push(rep.profile);
            }
        }
    }
    out
}

#[test]
fn envelope_mode_is_bit_identical_to_the_pr3_scalar_timeline() {
    // the `--no-backfill` acceptance pin: replay a deterministic stream
    // of real profiles through the new envelope timeline and the PR 3
    // reference — every dispatch instant must match exactly, per-core
    // split and all
    let profiles = profile_zoo();
    let mut rng = SplitMix64::new(0xBACC_F111);
    let mut env = ResourceTimeline::envelope();
    let mut reference = Pr3Timeline::default();
    for step in 0..80 {
        let p = &profiles[rng.below(profiles.len() as u64) as usize];
        let base = [0usize, 5, 11][rng.below(3) as usize];
        let nb = rng.below(1 << 22);
        let t_new = env.earliest_start(p, ResMap::arrays(base), nb);
        let t_ref = reference.earliest_start(p, base, nb);
        assert_eq!(t_new, t_ref, "step {step}: envelope dispatch diverged");
        env.commit(t_new, p, ResMap::arrays(base));
        reference.commit(t_ref, p, base);
        // the envelope frontiers agree wherever the reference tracks one
        for s in &p.spans {
            if let Some(r) = Pr3Timeline::fuse(s.res, base) {
                assert_eq!(
                    env.free_at(r),
                    *reference.free.get(&r).unwrap_or(&0),
                    "step {step}: frontier of res {r}"
                );
            }
        }
    }
}

/// conv (IMA arrays) followed by a residual add (cores): the add is the
/// only core section, so the batch profile is one array phase and one
/// trailing core interval — the geometry the gap scenarios build on.
fn conv_add_net(name: &str, hw: usize, cin: usize, cout: usize) -> Network {
    Network {
        name: name.into(),
        layers: vec![
            Layer::conv("conv", hw, hw, cin, cout).with_relu(),
            Layer::add("add", hw, hw, cout, 0),
        ],
    }
}

/// One-request-per-tenant serve config over `n_arrays` (t=0 traces,
/// strict 1-wide window).
fn one_shot_cfg(n_arrays: usize) -> ServeConfig {
    ServeConfig {
        n_arrays,
        window: BatchWindow {
            max_batch: 1,
            max_wait_cy: 0,
        },
        duration_s: 0.01,
        ..ServeConfig::default()
    }
}

fn one_shot_models(nets: &[Network]) -> Vec<ModelTraffic> {
    nets.iter()
        .map(|net| ModelTraffic {
            net: net.clone(),
            traffic: TrafficModel::Trace {
                arrivals_cy: vec![0],
            },
            weight: 1,
        })
        .collect()
}

/// Batch-of-one report for tenant `i` of `nets` placed exactly as the
/// serving simulator places them.
fn tenant_report(nets: &[Network], n_arrays: usize, i: usize) -> BatchReport {
    let cfg = SystemConfig::scaled_up(n_arrays);
    let pm = PowerModel::paper();
    let mut cache = PlanCache::new();
    let tenancy = place_tenants(nets, 256, n_arrays, false, &mut cache).unwrap();
    run_batched(
        &nets[i],
        Strategy::ImaDw,
        &cfg,
        &pm,
        &tenancy.tenants[i].plan,
        BatchConfig {
            batch: 1,
            ..BatchConfig::default()
        },
    )
}

#[test]
fn backfill_strictly_beats_envelope_on_a_core_tail_gap() {
    // tenant A: a long conv phase, then a core tail. tenant B: a short
    // conv, then a core section that fits entirely *before* A's core
    // tail begins. The envelope arbiter holds B until A releases the
    // cores; the backfilling arbiter slots B's core interval into the
    // gap and B drains inside A's shadow — the makespans are exactly
    // computable from the two profiles.
    let pm = PowerModel::paper();
    let nets = [conv_add_net("wide", 64, 128, 256), conv_add_net("narrow", 8, 64, 64)];
    let n_arrays = 4;
    let a = tenant_report(&nets, n_arrays, 0);
    let b = tenant_report(&nets, n_arrays, 1);
    let a_c0 = a.profile.span(RES_CORE0).expect("wide add runs on cores");
    let b_c0 = b.profile.span(RES_CORE0).expect("narrow add runs on cores");

    // scenario preconditions, asserted so model drift reports loudly:
    // B's whole core section fits before A first touches the cores, A's
    // core envelope really does gate B, both adds fill all eight cores
    // (so affinity rotation is a pure permutation), and the core tail
    // closes each batch
    assert!(b_c0.last_release <= a_c0.first_use, "narrow core section must fit the gap");
    assert!(a_c0.last_release > b_c0.first_use, "envelope must gate the narrow tenant");
    assert!(b.cycles < a.cycles);
    assert_eq!(a_c0.last_release, a.cycles);
    assert!(a.profile.span(RES_CORE0 + 7).is_some());
    assert!(b.profile.span(RES_CORE0 + 7).is_some());
    for s in &b.profile.spans {
        assert!(s.res < N_CORES || s.res >= RES_ARRAY0, "only cores/arrays contended");
    }

    let models = one_shot_models(&nets);
    let base = one_shot_cfg(n_arrays);
    let bf = simulate(&models, &base, &pm).unwrap();
    let env = simulate(
        &models,
        &ServeConfig {
            backfill: false,
            ..base
        },
        &pm,
    )
    .unwrap();
    assert_eq!(bf.total_served(), 2);
    assert_eq!(env.total_served(), 2);
    assert!(bf.tenants.iter().all(|t| t.n_passes == 1));

    // exact makespans: envelope delays B by A's core release minus B's
    // own core offset; backfill hides B entirely inside A's array phase
    let td_env = a_c0.last_release - b_c0.first_use;
    assert_eq!(env.makespan_cycles, a.cycles.max(td_env + b.cycles));
    assert_eq!(bf.makespan_cycles, a.cycles);
    assert!(
        bf.makespan_cycles < env.makespan_cycles,
        "{} !< {}",
        bf.makespan_cycles,
        env.makespan_cycles
    );
}

#[test]
fn core_rotation_shares_the_complex_between_small_tenants() {
    // two identical tenants whose residual sections engage only four
    // cores (2048 elements = 4 work chunks): under envelope dispatch
    // (fused complex, affinity 0 for everyone) the second tenant waits
    // out the first tenant's core section; under backfilling dispatch
    // the affinity rotation (bases 0 and 4) puts them on disjoint
    // physical cores and both drain in lockstep
    let pm = PowerModel::paper();
    let nets = [conv_add_net("tiny-a", 8, 32, 32), conv_add_net("tiny-b", 8, 32, 32)];
    let n_arrays = 4;
    let a = tenant_report(&nets, n_arrays, 0);
    let b = tenant_report(&nets, n_arrays, 1);
    let a_c0 = a.profile.span(RES_CORE0).expect("add runs on cores");
    let b_c0 = b.profile.span(RES_CORE0).expect("add runs on cores");

    // preconditions: the adds engage exactly four cores, so rotated
    // tenants are spatially disjoint on the complex
    assert!(a.profile.span(RES_CORE0 + 3).is_some(), "2048 elems = 4 chunks");
    assert!(a.profile.span(RES_CORE0 + 4).is_none(), "no fifth core engaged");
    assert!(b.profile.span(RES_CORE0 + 4).is_none());
    assert!(a_c0.last_release > b_c0.first_use, "envelope must serialize them");

    let models = one_shot_models(&nets);
    let base = one_shot_cfg(n_arrays);
    let bf = simulate(&models, &base, &pm).unwrap();
    let env = simulate(
        &models,
        &ServeConfig {
            backfill: false,
            ..base
        },
        &pm,
    )
    .unwrap();
    assert_eq!(bf.total_served(), 2);
    assert_eq!(env.total_served(), 2);

    // exact: envelope delays tenant B by A's core release minus B's core
    // offset; rotation removes the conflict entirely
    let td_env = a_c0.last_release - b_c0.first_use;
    assert_eq!(env.makespan_cycles, a.cycles.max(td_env + b.cycles));
    assert_eq!(bf.makespan_cycles, a.cycles.max(b.cycles));
    assert!(bf.makespan_cycles < env.makespan_cycles);

    // and the rotation shows up in the per-core utilization rows: cores
    // 4..7 carry tenant B's section under backfilling only
    let busy_of = |rep: &imcc::serve::ServeReport, name: &str| {
        rep.resource_busy
            .iter()
            .find(|r| r.name.as_ref() == name)
            .map(|r| r.busy_cycles)
            .unwrap_or(0)
    };
    assert!(busy_of(&bf, "core4") > 0, "rotated tenant lands on core4");
    assert_eq!(busy_of(&env, "core4"), 0, "envelope keeps everyone at affinity 0");
}

#[test]
fn backfilled_serve_table_is_bit_identical_across_runs() {
    let pm = PowerModel::paper();
    let scfg = ServeConfig {
        seed: 0x00FF_111E,
        duration_s: 0.1,
        ..ServeConfig::default()
    };
    let a = simulate(&mnv2_bottleneck_pair(250.0), &scfg, &pm).unwrap();
    let b = simulate(&mnv2_bottleneck_pair(250.0), &scfg, &pm).unwrap();
    assert!(a.backfill, "default dispatch backfills");
    assert!(a.render_table().contains("backfilled dispatch"));
    assert_eq!(a.render_table(), b.render_table());
    assert_eq!(a.makespan_cycles, b.makespan_cycles);
    assert_eq!(a.busy_cycles, b.busy_cycles);
    assert_eq!(a.peak_backlog, b.peak_backlog);
    for (x, y) in a.tenants.iter().zip(b.tenants.iter()) {
        assert_eq!(x.latency.percentiles(), y.latency.percentiles());
        assert_eq!((x.served, x.batches, x.dropped), (y.served, y.batches, y.dropped));
    }
}

#[test]
fn no_backfill_serve_is_deterministic_and_conserved_on_the_poisson_mix() {
    // the canonical two-model Poisson mix: `--no-backfill` output is
    // deterministic (and labeled as the PR 3 overlapped dispatch), both
    // modes serve every arrival, and the backfilled makespan never
    // exceeds the envelope one — the same conservation CI smoke-checks
    // fleet-wide
    let pm = PowerModel::paper();
    let env_cfg = ServeConfig {
        backfill: false,
        duration_s: 0.05,
        ..ServeConfig::default()
    };
    let env = simulate(&mnv2_bottleneck_pair(300.0), &env_cfg, &pm).unwrap();
    let again = simulate(&mnv2_bottleneck_pair(300.0), &env_cfg, &pm).unwrap();
    assert!(!env.backfill);
    assert!(env.render_table().contains("overlapped dispatch"));
    assert_eq!(env.render_table(), again.render_table());

    let bf = simulate(
        &mnv2_bottleneck_pair(300.0),
        &ServeConfig {
            duration_s: 0.05,
            ..ServeConfig::default()
        },
        &pm,
    )
    .unwrap();
    assert_eq!(bf.total_served(), env.total_served());
    assert_eq!(bf.total_dropped(), 0);
    assert!(
        bf.makespan_cycles <= env.makespan_cycles,
        "backfilled {} > envelope {}",
        bf.makespan_cycles,
        env.makespan_cycles
    );
}
