//! Property and regression tests for the next-event queue and the
//! gap-skip timeline fast paths (no artifacts needed).
//!
//! Contract one — **queue-structure transparency**: the calendar queue
//! and the binary heap realize the identical total order on (dispatch
//! instant, tenant id), so a serving run is bit-identical between
//! `--event-queue calendar` and `--event-queue heap` on *everything*:
//! dispatch tables, full serve JSON (work counters included — pushes,
//! pops, and stale revalidations are functions of the shared pop
//! sequence), and exported Chrome-trace bytes. Checked across random
//! Poisson/MMPP-2 fleets, every arbitration policy, and
//! admission+autoscale runs.
//!
//! Contract two — **gap-skip neutrality and profit**: the timeline's
//! append-at-tail / no-usable-gap fast paths never change a dispatch
//! decision (tables and makespans identical with `--no-gap-skip`), and
//! on a long horizon they strictly cut the deterministic `probes`
//! counter — the win the perf gates pin.

use imcc::arch::PowerModel;
use imcc::coordinator::PlanCache;
use imcc::serve::trace::chrome_trace;
use imcc::serve::{
    simulate, simulate_traced, EventQueue, EventQueueKind, ModelTraffic, Policy, ServeConfig,
    ServeReport, TraceRecorder, TrafficModel,
};
use imcc::util::prop;
use imcc::util::rng::SplitMix64;

/// `n` bottleneck tenants with one random traffic model each.
fn random_fleet(rng: &mut SplitMix64, n: usize) -> Vec<ModelTraffic> {
    (0..n)
        .map(|i| {
            let mut net = imcc::net::bottleneck::bottleneck();
            net.name = format!("bn-{i}");
            let rate_per_s = 50.0 + rng.next_f64() * 350.0;
            let traffic = if rng.below(2) == 1 {
                TrafficModel::Bursty {
                    rate_per_s,
                    burst: 2.0 + rng.next_f64() * 4.0,
                    dwell_s: 0.002 + rng.next_f64() * 0.01,
                }
            } else {
                TrafficModel::Poisson { rate_per_s }
            };
            ModelTraffic { net, traffic, weight: 1 + rng.below(3) }
        })
        .collect()
}

/// The full cross-mode pin: dispatch table, serve JSON (bytes), and the
/// deterministic counters must agree between queue kinds.
fn assert_modes_identical(cal: &ServeReport, heap: &ServeReport, ctx: &str) {
    assert_eq!(cal.render_table(), heap.render_table(), "{ctx}: dispatch tables");
    assert_eq!(
        cal.to_json().to_string_pretty(),
        heap.to_json().to_string_pretty(),
        "{ctx}: serve JSON bytes"
    );
    // spelled out again so a failure names the counter, not a JSON diff
    assert_eq!(cal.counters, heap.counters, "{ctx}: counters");
    assert_eq!(cal.makespan_cycles, heap.makespan_cycles, "{ctx}: makespan");
    assert!(cal.counters.evq_pops <= cal.counters.evq_pushes, "{ctx}: pop/push conservation");
}

fn run(models: &[ModelTraffic], scfg: &ServeConfig) -> ServeReport {
    let pm = PowerModel::paper();
    simulate(models, scfg, &pm).expect("serve run")
}

#[test]
fn calendar_and_heap_are_bit_identical_on_random_fleets() {
    prop::check("evq_bit_identity", 10, |rng: &mut SplitMix64| {
        let n = rng.range_i64(1, 4) as usize;
        let models = random_fleet(rng, n);
        let policy = [Policy::Fifo, Policy::Wrr, Policy::Sjf][rng.below(3) as usize];
        let base = ServeConfig {
            n_arrays: 6 * n,
            policy,
            backfill: rng.below(2) == 1,
            prune: rng.below(2) == 1,
            seed: rng.next_u64(),
            duration_s: 0.02 + rng.next_f64() * 0.03,
            deadline_cy: [0u64, 2_000_000][rng.below(2) as usize],
            ..ServeConfig::default()
        };
        assert_eq!(base.event_queue, EventQueueKind::Calendar, "calendar is the default");
        let cal = run(&models, &base);
        let heap = run(
            &models,
            &ServeConfig { event_queue: EventQueueKind::Heap, ..base.clone() },
        );
        let ctx = format!(
            "{} tenants, {:?}, backfill {}, prune {}, seed {:#x}",
            n, policy, base.backfill, base.prune, base.seed
        );
        assert_modes_identical(&cal, &heap, &ctx);
        assert!(cal.counters.evq_pushes > 0, "{ctx}: the loop never used the queue");
    });
}

#[test]
fn adversarial_interleaving_pops_in_identical_order() {
    // the structure-level half of contract one: drive both queues with
    // one adversarial op sequence — same-instant bursts (the hi == lo
    // resize degenerate), pushes *below* the last popped instant mixed
    // with stale marks (the calendar-extraction interleaving the bugfix
    // pins), and wide-spread pushes that force re-bucketing — and demand
    // entry-for-entry pop identity plus matching push/pop/stale counters
    prop::check("evq_adversarial_interleaving", 20, |rng: &mut SplitMix64| {
        let mut cal = EventQueue::new(EventQueueKind::Calendar);
        let mut heap = EventQueue::new(EventQueueKind::Heap);
        let mut last_pop: u64 = 0;
        let mut id: usize = 0;
        let mut live: usize = 0;
        for _ in 0..rng.range_i64(100, 400) {
            match rng.below(8) {
                // burst of same-instant events
                0 => {
                    let t = last_pop + rng.below(4);
                    for _ in 0..rng.range_i64(2, 6) {
                        cal.push(t, id);
                        heap.push(t, id);
                        id += 1;
                        live += 1;
                    }
                }
                // push below the last popped instant
                1 | 2 => {
                    let t = last_pop.saturating_sub(rng.below(1000));
                    cal.push(t, id);
                    heap.push(t, id);
                    id += 1;
                    live += 1;
                }
                // push ahead, spread wide enough to trigger re-bucketing
                3 | 4 => {
                    let t = last_pop + 1 + rng.below(100_000);
                    cal.push(t, id);
                    heap.push(t, id);
                    id += 1;
                    live += 1;
                }
                // pop, sometimes marking the popped entry stale (a pure
                // counter — must stay mode-independent)
                _ => {
                    assert_eq!(cal.peek(), heap.peek(), "peek before pop");
                    let (c, h) = (cal.pop(), heap.pop());
                    assert_eq!(c, h, "pop order");
                    if let Some((t, _)) = c {
                        last_pop = t;
                        live -= 1;
                        if rng.below(3) == 0 {
                            cal.mark_stale();
                            heap.mark_stale();
                        }
                    }
                }
            }
        }
        // drain: the remaining order must be identical entry for entry
        loop {
            assert_eq!(cal.peek(), heap.peek(), "peek during drain");
            let (c, h) = (cal.pop(), heap.pop());
            assert_eq!(c, h, "drain pop order");
            if c.is_none() {
                break;
            }
            live -= 1;
        }
        assert_eq!(live, 0, "every push popped exactly once");
        let (cc, hc) = (cal.counters(), heap.counters());
        assert_eq!(cc.pushes, hc.pushes, "push counters");
        assert_eq!(cc.pops, hc.pops, "pop counters");
        assert_eq!(cc.stale, hc.stale, "stale counters");
        assert_eq!(cc.pushes, cc.pops, "conservation after drain");
    });
}

#[test]
fn calendar_and_heap_agree_under_admission_and_autoscale() {
    // the control plane re-plans mid-run and floors dispatches — the
    // heaviest revalidation churn the queue sees; both structures must
    // still realize the same order
    let models = random_fleet(&mut SplitMix64::new(0xE7_07), 3);
    for policy in [Policy::Fifo, Policy::Wrr, Policy::Sjf] {
        let base = ServeConfig {
            n_arrays: 20,
            policy,
            headroom: 2,
            slo_p95_cy: 150_000_000,
            autoscale: true,
            duration_s: 0.04,
            ..ServeConfig::default()
        };
        let cal = run(&models, &base);
        let heap =
            run(&models, &ServeConfig { event_queue: EventQueueKind::Heap, ..base.clone() });
        assert_modes_identical(&cal, &heap, &format!("controlled, {policy:?}"));
        assert_eq!(
            cal.scale_events.len(),
            heap.scale_events.len(),
            "controlled, {policy:?}: scale-event traces"
        );
    }
}

#[test]
fn trace_bytes_are_identical_across_queue_modes() {
    let models = random_fleet(&mut SplitMix64::new(0xBEEF), 2);
    let pm = PowerModel::paper();
    let mut bytes = Vec::new();
    for kind in [EventQueueKind::Calendar, EventQueueKind::Heap] {
        let scfg = ServeConfig {
            n_arrays: 12,
            event_queue: kind,
            duration_s: 0.02,
            ..ServeConfig::default()
        };
        let mut cache = PlanCache::with_capacity(scfg.plan_cache_cap);
        let mut rec = TraceRecorder::on(1 << 22);
        let rep = simulate_traced(&models, &scfg, &pm, &mut cache, &mut rec).expect("traced run");
        let tr = rec.finish().expect("recorder was on");
        bytes.push(chrome_trace(&rep, &tr).to_string_pretty());
    }
    assert_eq!(bytes[0], bytes[1], "chrome-trace export must not see the queue structure");
}

#[test]
fn gap_skip_is_dispatch_invisible_and_cuts_probes_long_horizon() {
    // neutrality on random fleets at short horizons...
    prop::check("gap_skip_neutrality", 8, |rng: &mut SplitMix64| {
        let n = rng.range_i64(1, 4) as usize;
        let models = random_fleet(rng, n);
        let base = ServeConfig {
            n_arrays: 6 * n,
            backfill: rng.below(2) == 1,
            seed: rng.next_u64(),
            duration_s: 0.02 + rng.next_f64() * 0.02,
            ..ServeConfig::default()
        };
        let fast = run(&models, &base);
        let slow = run(&models, &ServeConfig { gap_skip: false, ..base.clone() });
        let ctx = format!("seed {:#x}, backfill {}", base.seed, base.backfill);
        assert_eq!(fast.render_table(), slow.render_table(), "{ctx}: dispatch tables");
        assert_eq!(fast.makespan_cycles, slow.makespan_cycles, "{ctx}: makespan");
        assert_eq!(fast.busy_cycles, slow.busy_cycles, "{ctx}: busy union");
        assert_eq!(fast.counters.steps, slow.counters.steps, "{ctx}: event-loop steps");
        assert_eq!(fast.counters.validations, slow.counters.validations, "{ctx}: validations");
        // the queue sees the identical pop sequence either way
        assert_eq!(fast.counters.evq_pushes, slow.counters.evq_pushes, "{ctx}: evq pushes");
        assert_eq!(fast.counters.evq_stale, slow.counters.evq_stale, "{ctx}: evq stale");
        assert!(
            fast.counters.probes <= slow.counters.probes,
            "{ctx}: fast paths added probe work ({} > {})",
            fast.counters.probes,
            slow.counters.probes
        );
    });
    // ...and strict profit on a long backfilled horizon (the acceptance
    // gate `imcc bench-timeline` also enforces at its 10× point)
    let models = random_fleet(&mut SplitMix64::new(0x6A9), 3);
    let base = ServeConfig { n_arrays: 18, duration_s: 0.2, ..ServeConfig::default() };
    let fast = run(&models, &base);
    let slow = run(&models, &ServeConfig { gap_skip: false, ..base.clone() });
    assert_eq!(fast.render_table(), slow.render_table(), "long horizon: dispatch tables");
    assert_eq!(fast.makespan_cycles, slow.makespan_cycles, "long horizon: makespan");
    assert!(
        fast.counters.probes < slow.counters.probes,
        "long horizon: gap-skip must strictly cut probes ({} !< {})",
        fast.counters.probes,
        slow.counters.probes
    );
}
