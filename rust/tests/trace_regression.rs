//! Execution-trace regression pins (no artifacts needed).
//!
//! The trace recorder's contract is *observation without perturbation*,
//! and its exported spans must be a faithful replay of what the
//! simulator committed. Three families of pins:
//!
//! * **bit-identity** — a traced run and an untraced run of the same
//!   seeded configuration produce the same dispatch table, the same
//!   counters, and the same serve JSON, across Poisson and MMPP-2
//!   traffic, with and without the admission/autoscale controllers, and
//!   under serialized (`--no-overlap`) dispatch;
//! * **conservation** — the trace's occupancy spans merge to exactly the
//!   committed busy-interval sets the timeline drained with (pruning off
//!   so the full history survives), every request's five decomposition
//!   phases sum to its end-to-end latency, and every traced rejection's
//!   `predicted_cy` exceeds the admission budget;
//! * **determinism** — two runs under the same seed export byte-identical
//!   Chrome traces, and the bounded buffer drops oldest-first with an
//!   exact `truncated_events` count.

use std::collections::BTreeMap;

use imcc::arch::PowerModel;
use imcc::coordinator::{IntervalSet, PlanCache};
use imcc::net::bottleneck::bottleneck;
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::serve::trace::{chrome_trace, TraceEvent};
use imcc::serve::{
    mnv2_bottleneck_pair, simulate_traced, AdmissionControl, ModelTraffic, Policy, ServeConfig,
    ServeReport, ServeTrace, TraceRecorder, TrafficModel,
};

/// Run one configuration twice — recorder off, recorder on — and return
/// both reports plus the captured trace.
fn run_pair(models: &[ModelTraffic], scfg: &ServeConfig) -> (ServeReport, ServeReport, ServeTrace) {
    let pm = PowerModel::paper();
    let mut cache = PlanCache::with_capacity(scfg.plan_cache_cap);
    let off = simulate_traced(models, scfg, &pm, &mut cache, &mut TraceRecorder::Off)
        .expect("untraced run");
    let mut cache2 = PlanCache::with_capacity(scfg.plan_cache_cap);
    let mut rec = TraceRecorder::on(1 << 22);
    let on = simulate_traced(models, scfg, &pm, &mut cache2, &mut rec).expect("traced run");
    let tr = rec.finish().expect("recorder was on");
    (off, on, tr)
}

/// Every observable the regression suite pins elsewhere, compared across
/// the traced/untraced pair.
fn assert_identical(off: &ServeReport, on: &ServeReport, ctx: &str) {
    assert_eq!(off.render_table(), on.render_table(), "{ctx}: dispatch tables");
    assert_eq!(
        off.render_breakdown(),
        on.render_breakdown(),
        "{ctx}: decomposition tables"
    );
    assert_eq!(
        off.to_json().to_string_pretty(),
        on.to_json().to_string_pretty(),
        "{ctx}: serve JSON"
    );
    assert_eq!(off.counters.steps, on.counters.steps, "{ctx}: steps");
    assert_eq!(off.counters.validations, on.counters.validations, "{ctx}: validations");
    assert_eq!(off.counters.probes, on.counters.probes, "{ctx}: probes");
    assert_eq!(
        off.counters.live_intervals, on.counters.live_intervals,
        "{ctx}: live intervals"
    );
}

fn poisson_pair(rate: f64) -> Vec<ModelTraffic> {
    mnv2_bottleneck_pair(rate)
}

fn bursty_pair(rate: f64) -> Vec<ModelTraffic> {
    vec![
        ModelTraffic {
            net: mobilenet_v2(224),
            traffic: TrafficModel::Bursty {
                rate_per_s: rate,
                burst: 6.0,
                dwell_s: 0.004,
            },
            weight: 3,
        },
        ModelTraffic {
            net: bottleneck(),
            traffic: TrafficModel::Bursty {
                rate_per_s: rate * 2.0,
                burst: 4.0,
                dwell_s: 0.002,
            },
            weight: 1,
        },
    ]
}

fn base_cfg() -> ServeConfig {
    ServeConfig {
        n_arrays: 24,
        duration_s: 0.02,
        ..ServeConfig::default()
    }
}

#[test]
fn traced_run_is_bit_identical_poisson() {
    let scfg = base_cfg();
    let (off, on, tr) = run_pair(&poisson_pair(400.0), &scfg);
    assert_identical(&off, &on, "poisson/backfilled");
    assert_eq!(tr.truncated_events, 0);
    assert!(
        tr.events.iter().any(|e| matches!(e, TraceEvent::Batch(_))),
        "a served run must record batch spans"
    );
}

#[test]
fn traced_run_is_bit_identical_bursty_wrr() {
    let scfg = ServeConfig {
        policy: Policy::Wrr,
        ..base_cfg()
    };
    let (off, on, _) = run_pair(&bursty_pair(600.0), &scfg);
    assert_identical(&off, &on, "mmpp2/wrr");
}

#[test]
fn traced_run_is_bit_identical_serialized() {
    let scfg = ServeConfig {
        overlap: false,
        ..base_cfg()
    };
    let (off, on, tr) = run_pair(&poisson_pair(400.0), &scfg);
    assert_identical(&off, &on, "serialized");
    // serialized dispatch has no per-resource profile commits to replay
    assert!(
        !tr.events
            .iter()
            .any(|e| matches!(e, TraceEvent::Occupancy { .. })),
        "no occupancy spans without per-resource dispatch"
    );
}

#[test]
fn traced_run_is_bit_identical_with_controllers() {
    // staged MobileNetV2 under burst pressure with headroom: the
    // autoscaler migrates, admission sheds — the trace must observe both
    // without perturbing either
    let scfg = ServeConfig {
        n_arrays: 16,
        headroom: 8,
        autoscale: true,
        slo_p95_cy: 3_000_000,
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    let models = vec![ModelTraffic {
        net: mobilenet_v2(224),
        traffic: TrafficModel::Bursty {
            rate_per_s: 4_000.0,
            burst: 8.0,
            dwell_s: 0.005,
        },
        weight: 1,
    }];
    let (off, on, tr) = run_pair(&models, &scfg);
    assert_identical(&off, &on, "autoscale+slo");
    assert!(
        !off.scale_events.is_empty(),
        "precondition: the controller must actually migrate"
    );
    let scales = tr
        .events
        .iter()
        .filter(|e| matches!(e, TraceEvent::Scale(_)))
        .count();
    assert_eq!(
        scales,
        off.scale_events.len(),
        "one trace instant per applied resize"
    );
}

#[test]
fn occupancy_spans_merge_to_the_committed_timeline() {
    // pruning off so the drained timeline still holds the whole history
    for backfill in [true, false] {
        let scfg = ServeConfig {
            prune: false,
            backfill,
            ..base_cfg()
        };
        let (_, _, tr) = run_pair(&poisson_pair(400.0), &scfg);
        let merged = tr.merged_occupancy();
        let committed: BTreeMap<usize, IntervalSet> = tr
            .final_intervals
            .iter()
            .map(|(res, iv)| {
                let mut s = IntervalSet::default();
                for &(a, b) in iv {
                    s.insert(a, b);
                }
                (*res, s)
            })
            .collect();
        assert!(!committed.is_empty(), "a served run commits busy intervals");
        assert_eq!(
            merged, committed,
            "backfill={backfill}: trace occupancy must replay the committed timeline exactly"
        );
    }
}

#[test]
fn occupancy_conservation_holds_under_autoscale() {
    // migrations commit programming profiles outside the batch path; the
    // recorder replays them as batch-0 occupancy so conservation holds
    let scfg = ServeConfig {
        n_arrays: 16,
        headroom: 8,
        autoscale: true,
        prune: false,
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    let models = vec![ModelTraffic {
        net: mobilenet_v2(224),
        traffic: TrafficModel::Bursty {
            rate_per_s: 4_000.0,
            burst: 8.0,
            dwell_s: 0.005,
        },
        weight: 1,
    }];
    let (off, _, tr) = run_pair(&models, &scfg);
    assert!(!off.scale_events.is_empty(), "precondition: a migration happened");
    assert!(
        tr.events
            .iter()
            .any(|e| matches!(e, TraceEvent::Occupancy { batch: 0, .. })),
        "migration programming must appear as batch-0 occupancy"
    );
    let merged = tr.merged_occupancy();
    for (res, iv) in &tr.final_intervals {
        let mut s = IntervalSet::default();
        for &(a, b) in iv {
            s.insert(a, b);
        }
        assert_eq!(
            merged.get(res),
            Some(&s),
            "resource {res}: replayed occupancy must cover migrations too"
        );
    }
}

#[test]
fn decomposition_sums_to_latency_for_every_tenant() {
    for scfg in [
        base_cfg(),
        ServeConfig {
            overlap: false,
            ..base_cfg()
        },
        ServeConfig {
            policy: Policy::Sjf,
            backfill: false,
            ..base_cfg()
        },
    ] {
        let (off, _, _) = run_pair(&bursty_pair(800.0), &scfg);
        for s in &off.tenants {
            assert!(s.served > 0, "{}: precondition — something was served", s.name);
            assert_eq!(
                s.breakdown.components_sum(),
                s.latency.sum(),
                "{}: phase cycles must sum to end-to-end latency cycles",
                s.name
            );
            let counts: Vec<u64> = s.breakdown.phases().iter().map(|(_, h)| h.count()).collect();
            assert!(
                counts.iter().all(|&c| c == s.served),
                "{}: every phase histogram records every served request",
                s.name
            );
        }
        // pool-wide stall attribution re-aggregates the same cycles
        let attributed: u64 = off.stall_by_resource.iter().map(|s| s.stalled_cycles).sum();
        let stalled: u128 = off
            .tenants
            .iter()
            .map(|s| s.breakdown.resource_stall.sum())
            .sum();
        assert_eq!(attributed as u128, stalled, "stall shares conserve stalled cycles");
    }
}

#[test]
fn traced_rejections_exceed_the_admission_budget() {
    let budget = 2_000_000u64;
    let scfg = ServeConfig {
        n_arrays: 16,
        slo_p95_cy: budget,
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    let models = vec![ModelTraffic {
        net: mobilenet_v2(224),
        traffic: TrafficModel::Poisson { rate_per_s: 5_000.0 },
        weight: 1,
    }];
    let (off, _, tr) = run_pair(&models, &scfg);
    assert!(off.tenants[0].rejected > 0, "precondition: the gate must refuse");
    // the gate's documented contract: budget() is the threshold every
    // traced rejection's prediction exceeded
    let ac = AdmissionControl::new(budget, &scfg.window, vec![1]);
    assert_eq!(ac.budget(), budget);
    let mut rejects = 0u64;
    for e in &tr.events {
        if let TraceEvent::Reject { predicted_cy, arrival, t, .. } = e {
            assert!(
                *predicted_cy > budget,
                "a traced rejection must carry a prediction over budget"
            );
            assert!(arrival <= t, "rejection instants follow their arrivals");
            rejects += 1;
        }
    }
    assert_eq!(rejects, off.tenants[0].rejected, "one Reject event per refusal");
}

#[test]
fn chrome_trace_bytes_are_seed_deterministic() {
    let scfg = ServeConfig {
        n_arrays: 16,
        headroom: 8,
        autoscale: true,
        slo_p95_cy: 3_000_000,
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    let models = bursty_pair(2_000.0);
    let (_, on_a, tr_a) = run_pair(&models, &scfg);
    let (_, on_b, tr_b) = run_pair(&models, &scfg);
    let bytes_a = chrome_trace(&on_a, &tr_a).to_string_pretty();
    let bytes_b = chrome_trace(&on_b, &tr_b).to_string_pretty();
    assert_eq!(bytes_a, bytes_b, "identical seeds must export identical bytes");
    // a different seed moves the arrivals, hence the trace
    let moved = ServeConfig {
        seed: scfg.seed ^ 1,
        ..scfg
    };
    let (_, on_c, tr_c) = run_pair(&models, &moved);
    assert_ne!(
        bytes_a,
        chrome_trace(&on_c, &tr_c).to_string_pretty(),
        "a moved seed must move the trace"
    );
}

#[test]
fn trace_limit_drops_oldest_and_counts() {
    let pm = PowerModel::paper();
    let scfg = base_cfg();
    let models = poisson_pair(400.0);
    // unbounded first, to learn the full event count
    let mut cache = PlanCache::with_capacity(scfg.plan_cache_cap);
    let mut rec = TraceRecorder::on(1 << 22);
    simulate_traced(&models, &scfg, &pm, &mut cache, &mut rec).expect("full run");
    let full = rec.finish().expect("recorder was on");
    assert!(full.events.len() > 8, "precondition: enough events to truncate");
    assert_eq!(full.truncated_events, 0);

    let limit = 8usize;
    let mut cache2 = PlanCache::with_capacity(scfg.plan_cache_cap);
    let mut rec2 = TraceRecorder::on(limit);
    simulate_traced(&models, &scfg, &pm, &mut cache2, &mut rec2).expect("bounded run");
    let cut = rec2.finish().expect("recorder was on");
    assert_eq!(cut.events.len(), limit);
    assert_eq!(
        cut.truncated_events,
        (full.events.len() - limit) as u64,
        "dropped exactly the overflow"
    );
    // survivors are the *newest* events: the tail of the unbounded run
    let tail = &full.events[full.events.len() - limit..];
    for (a, b) in cut.events.iter().zip(tail) {
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "oldest-first truncation");
    }
}
