//! Integration tests over the functional runtime.
//!
//! The job-level contract tests run everywhere (the native backend needs no
//! artifacts). The golden-vector tests — bit-exactness vs the JAX reference
//! — need `make artifacts` and **skip cleanly** when the artifact set is
//! absent (gated on the manifest/golden files under `$IMCC_ARTIFACTS`,
//! default `./artifacts`), so `cargo test -q` passes on a clean checkout.

use imcc::runtime::{functional, golden, Manifest, Runtime};

fn artifacts_dir() -> String {
    std::env::var("IMCC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Golden tests gate on the files they read actually being present.
fn have_artifact(rel: &str) -> bool {
    let path = format!("{}/{rel}", artifacts_dir());
    if std::path::Path::new(&path).exists() {
        true
    } else {
        eprintln!("skipping golden-vector test: `{path}` not found (run `make artifacts`)");
        false
    }
}

#[test]
fn backend_loads_and_executes() {
    let rt = Runtime::load(&artifacts_dir()).expect("native backend always loads");
    // a trivial residual run proves the job path actually executes
    let y = rt.residual(&[7i8; 4096], &[-3i8; 4096]).unwrap();
    assert!(y.iter().all(|&v| v == 4));
}

#[test]
fn mvm_artifact_matches_host_math() {
    let dir = artifacts_dir();
    let mut rt = Runtime::load(&dir).unwrap();
    // identity-ish weight tile: w[r][c] = 1 if r == c else 0
    let mut w = vec![0i8; 256 * 256];
    for i in 0..256 {
        w[i * 256 + i] = 1;
    }
    rt.program_weight_tile((9000, 0, 0), &w).unwrap();
    let mut x = vec![0i8; 16 * 256];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i * 7) % 251) as i8;
    }
    // identity weights, shift 0, no relu -> y == x
    let y = rt.mvm((9000, 0, 0), &x, 0, false, 16).unwrap();
    assert_eq!(y, x);
    // raw path returns the same values as int32
    let r = rt.mvm_raw((9000, 0, 0), &x, 16).unwrap();
    assert!(r.iter().zip(x.iter()).all(|(a, b)| *a == *b as i32));
}

#[test]
fn requant_matches_contract() {
    let rt = Runtime::load(&artifacts_dir()).unwrap();
    let mut acc = vec![0i32; 16 * 256];
    acc[0] = 1000; // (1000 + 4) >> 3 = 125
    acc[1] = -1000; // (-1000 + 4) >> 3 = -125
    acc[2] = 100_000; // clips to 127
    acc[3] = -100_000; // clips to -128
    let y = rt.requant(&acc, 3, false, 16).unwrap();
    assert_eq!(&y[..4], &[125, -125, 127, -128]);
    // relu clamps negatives to zero
    let yr = rt.requant(&acc, 3, true, 16).unwrap();
    assert_eq!(&yr[..4], &[125, 0, 127, 0]);
}

#[test]
fn dw_tile_artifact_center_tap() {
    let rt = Runtime::load(&artifacts_dir()).unwrap();
    // weights: only the center tap = 1 → output == input interior
    let mut w = vec![0i8; 9 * 16];
    for c in 0..16 {
        w[4 * 16 + c] = 1;
    }
    let mut x = vec![0i8; 18 * 18 * 16];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i * 13) % 127) as i8;
    }
    let y = rt.dw_tile(&x, &w, 0, false, 1).unwrap();
    for ty in 0..16 {
        for tx in 0..16 {
            for c in 0..16 {
                let xin = x[((ty + 1) * 18 + tx + 1) * 16 + c];
                assert_eq!(y[(ty * 16 + tx) * 16 + c], xin);
            }
        }
    }
}

#[test]
fn tiny_network_bit_exact_vs_jax_golden() {
    if !have_artifact("manifest_tiny.json") {
        return;
    }
    let dir = artifacts_dir();
    let m = Manifest::load(&dir, true).unwrap();
    let mut rt = Runtime::load(&dir).unwrap();
    functional::program_network(&mut rt, &m, 0.0).unwrap();
    let res = functional::run_inference(&rt, &m).unwrap();
    assert!(res.all_match(), "diverged at {:?}", res.first_divergent_layer());
    assert_eq!(res.logits, m.golden_logits);
    assert_eq!(res.argmax, m.golden_argmax);
}

#[test]
fn noise_changes_logits_but_not_catastrophically() {
    // conductance-noise ablation: σ=0.02 must perturb the logits while the
    // pipeline still runs end-to-end
    if !have_artifact("manifest_tiny.json") {
        return;
    }
    let dir = artifacts_dir();
    let m = Manifest::load(&dir, true).unwrap();
    let mut rt = Runtime::load(&dir).unwrap();
    functional::program_network(&mut rt, &m, 0.02).unwrap();
    let res = functional::run_inference(&rt, &m).unwrap();
    assert_ne!(res.logits, m.golden_logits, "σ=0.02 must perturb something");
    let l2: f64 = res
        .logits
        .iter()
        .zip(m.golden_logits.iter())
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let ref_norm: f64 = m.golden_logits.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
    assert!(l2 / ref_norm < 0.5, "drift {l2} vs norm {ref_norm}");
}

#[test]
fn fused_bottleneck_artifact_matches_golden() {
    if !have_artifact("golden/bottleneck_x.bin") {
        return;
    }
    let dir = artifacts_dir();
    let rt = Runtime::load(&dir).unwrap();
    let x = golden::load_i8(&format!("{dir}/golden/bottleneck_x.bin")).unwrap();
    let w1 = golden::load_i8(&format!("{dir}/golden/bottleneck_w1.bin")).unwrap();
    let wd = golden::load_i8(&format!("{dir}/golden/bottleneck_wd.bin")).unwrap();
    let w2 = golden::load_i8(&format!("{dir}/golden/bottleneck_w2.bin")).unwrap();
    let s = golden::load_i32(&format!("{dir}/golden/bottleneck_shifts.bin")).unwrap();
    let want = golden::load_i8(&format!("{dir}/golden/bottleneck_y.bin")).unwrap();
    let got = rt.bottleneck(&x, &w1, &wd, &w2, &[s[0], s[1], s[2]]).unwrap();
    assert_eq!(golden::first_mismatch(&got, &want), None);
}
