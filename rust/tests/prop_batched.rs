//! Property tests for the batched functional path (no artifacts needed).
//!
//! Pits the full conv-layer orchestration — virtual im2col, 128/16-pixel
//! chunking, row/column tiling, row-split int32 accumulation, requant —
//! against an independent host-side integer reference on random shapes,
//! reusing `util::prop` and `util::rng::SplitMix64` so every failure
//! reproduces from a printed seed.

use imcc::net::Layer;
use imcc::runtime::client::{requant_val, XBAR};
use imcc::runtime::functional::run_conv_layer;
use imcc::runtime::tensor::TensorI8;
use imcc::runtime::Runtime;
use imcc::util::prop;
use imcc::util::rng::SplitMix64;

/// Host reference of the numeric contract (DESIGN.md §4) for one linear
/// layer: acc = x·w (int32), round-shift, optional relu, clip.
fn host_linear(
    x: &[i8],
    w: &[i8],
    rows: usize,
    cols: usize,
    n_px: usize,
    shift: i32,
    relu: bool,
) -> Vec<i8> {
    let mut out = vec![0i8; n_px * cols];
    for p in 0..n_px {
        for c in 0..cols {
            let mut acc: i64 = 0;
            for r in 0..rows {
                acc += x[p * rows + r] as i64 * w[r * cols + c] as i64;
            }
            let mut v = if shift > 0 {
                (acc + (1i64 << (shift - 1))) >> shift
            } else {
                acc
            };
            if relu {
                v = v.max(0);
            }
            out[p * cols + c] = v.clamp(-128, 127) as i8;
        }
    }
    out
}

/// Program every crossbar tile of a bare conv layer, zero-padded to
/// 256×256 — the same layout `functional::program_network` uses.
fn program_layer(rt: &mut Runtime, li: usize, rows: usize, cols: usize, w: &[i8]) {
    let n_rt = rows.div_ceil(XBAR);
    let n_ct = cols.div_ceil(XBAR);
    for rt_i in 0..n_rt {
        for ct_i in 0..n_ct {
            let r0 = rt_i * XBAR;
            let c0 = ct_i * XBAR;
            let r_used = (rows - r0).min(XBAR);
            let c_used = (cols - c0).min(XBAR);
            let mut tile = vec![0i8; XBAR * XBAR];
            for r in 0..r_used {
                let src = (r0 + r) * cols + c0;
                tile[r * XBAR..r * XBAR + c_used].copy_from_slice(&w[src..src + c_used]);
            }
            rt.program_weight_tile((li, rt_i, ct_i), &tile).unwrap();
        }
    }
}

#[test]
fn batched_conv_layers_match_host_reference() {
    let mut rt = Runtime::load("unused").unwrap();
    // pre-generate cases (programming needs &mut Runtime)
    let mut cases = Vec::new();
    let mut rng = SplitMix64::new(0xBA7C_4ED0);
    for case in 0..6usize {
        // 1×1 convs over a random spatial extent: pixels span the 128-pixel
        // batched path, the 16-pixel tail, and sub-16 remainders
        let h = rng.range_i64(3, 12) as usize;
        let w_sp = rng.range_i64(3, 12) as usize;
        // cin beyond 256 exercises row-split accumulation, cout beyond 256
        // exercises column tiling
        let cin = rng.range_i64(1, 384) as usize;
        let cout = rng.range_i64(1, 384) as usize;
        let shift = rng.range_i64(0, 14) as i32;
        let relu = rng.below(2) == 1;

        let mut x = vec![0i8; h * w_sp * cin];
        rng.fill_i8(&mut x);
        let mut w = vec![0i8; cin * cout];
        rng.fill_i4(&mut w);

        let mut layer = Layer::conv(&format!("prop{case}"), h, w_sp, cin, cout);
        layer.shift = shift;
        if relu {
            layer = layer.with_relu();
        }
        program_layer(&mut rt, case, cin, cout, &w);
        cases.push((case, layer, x, w, h, w_sp, cin, cout, shift, relu));
    }

    for (li, layer, x, w, h, w_sp, cin, cout, shift, relu) in &cases {
        let input = TensorI8::from_vec(*h, *w_sp, *cin, x.clone());
        let (out, logits) = run_conv_layer(&rt, *li, layer, &input).unwrap();
        assert!(logits.is_none(), "conv layers produce tensors, not logits");
        assert_eq!((out.h, out.w, out.c), (*h, *w_sp, *cout));
        // k = 1, stride 1, pad 0: im2col row p is exactly pixel p's channels
        let want = host_linear(x, w, *cin, *cout, h * w_sp, *shift, *relu);
        assert_eq!(
            out.data, want,
            "case {li}: {h}x{w_sp}x{cin} -> {cout}, shift {shift}, relu {relu}"
        );
    }
}

#[test]
fn requant_matches_host_rule_exhaustively_random() {
    // the shared round-shift/relu/clip rule, pitted against a from-scratch
    // restatement under the seeded property harness
    prop::check("requant_host_rule", 256, |rng| {
        let acc = rng.range_i64(-5_000_000, 5_000_000);
        let shift = rng.range_i64(0, 20) as i32;
        let relu = rng.below(2) == 1;
        let mut v = if shift > 0 {
            (acc + (1i64 << (shift - 1))) >> shift
        } else {
            acc
        };
        if relu {
            v = v.max(0);
        }
        let want = v.clamp(-128, 127) as i8;
        assert_eq!(requant_val(acc, shift, relu), want, "acc {acc} shift {shift} relu {relu}");
    });
}

#[test]
fn batched_mvm_equals_chunked_mvm_on_random_tiles() {
    // the 128-pixel batched job must be bit-identical to eight 16-pixel
    // jobs — the invariant that lets the scheduler pick batch size freely
    let mut rt = Runtime::load("unused").unwrap();
    let mut rng = SplitMix64::new(0x5EED_0123);
    for case in 0..4usize {
        let mut w = vec![0i8; XBAR * XBAR];
        rng.fill_i4(&mut w);
        let key = (1000 + case, 0, 0);
        rt.program_weight_tile(key, &w).unwrap();
        let mut x = vec![0i8; 128 * XBAR];
        rng.fill_i8(&mut x);
        let shift = rng.range_i64(0, 12) as i32;
        let relu = rng.below(2) == 1;

        let big = rt.mvm(key, &x, shift, relu, 128).unwrap();
        for chunk in 0..8 {
            let lo = chunk * 16 * XBAR;
            let small = rt.mvm(key, &x[lo..lo + 16 * XBAR], shift, relu, 16).unwrap();
            assert_eq!(&big[lo..lo + 16 * XBAR], &small[..], "case {case} chunk {chunk}");
        }
        // and the raw + requant decomposition agrees with the fused path
        let raw = rt.mvm_raw(key, &x, 128).unwrap();
        let rq = rt.requant(&raw, shift, relu, 128).unwrap();
        assert_eq!(rq, big, "case {case}: raw+requant != fused");
    }
}
