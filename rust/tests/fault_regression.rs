//! Regression tests for deterministic fault injection and self-healing
//! (`serve::faults` + the chaos layer of `serve::fleet`).
//!
//! Five contracts:
//! 1. **Empty-plan identity** — a fleet with no fault plan carries no
//!    chaos ledger: no `faults` key in JSON, no fault lines in the
//!    table, and byte-identical output to a default `FleetConfig`.
//! 2. **Extended conservation** — under any fault plan, every offered
//!    request is served, dropped, rejected, or `lost_in_crash`, and
//!    every retried request is accounted exactly once
//!    (`retried == Σ failover.moved`).
//! 3. **Exact downtime** — crash/recover and drain spans price
//!    downtime to the cycle, clamped to the arrival horizon, and
//!    availability reflects it.
//! 4. **Seed determinism** — seeded fault plans and the failover
//!    cascade they trigger are pure functions of the seed: two runs
//!    render byte-identical tables and JSON.
//! 5. **Rolling updates lose nothing** — a staggered
//!    drain → reprogram → rejoin wave over a replica fleet takes every
//!    node down exactly once and loses zero requests.
//!
//! Plus the satellite: fleet-level replica autoscaling grows exactly
//! once after a sustained burst on a two-node replica fleet.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::serve::{
    bottleneck_fleet, mnv2_bottleneck_pair, simulate_fleet, AutoscaleConfig, FaultPlan,
    FleetConfig, ModelTraffic, RouterPolicy, ServeConfig, TrafficModel,
};

fn hot_mnv2(rate_per_s: f64) -> Vec<ModelTraffic> {
    vec![ModelTraffic {
        net: mobilenet_v2(224),
        traffic: TrafficModel::Poisson { rate_per_s },
        weight: 1,
    }]
}

/// The arrival horizon in cycles, derived exactly the way the fleet
/// derives it, so crafted fault instants land where the test intends.
fn horizon_cy(scfg: &ServeConfig) -> u64 {
    let cycle_ns = SystemConfig::scaled_up(scfg.n_arrays).freq.cycle_ns();
    (scfg.duration_s * 1e9 / cycle_ns) as u64
}

#[test]
fn empty_plan_runs_carry_no_chaos_ledger() {
    let pm = PowerModel::paper();
    let models = bottleneck_fleet(3, 200.0);
    let scfg = ServeConfig {
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    let default_cfg = FleetConfig::new(3, RouterPolicy::Hash);
    let mut explicit = FleetConfig::new(3, RouterPolicy::Hash);
    explicit.faults = FaultPlan::none();
    let a = simulate_fleet(&models, &scfg, &default_cfg, &pm).unwrap();
    let b = simulate_fleet(&models, &scfg, &explicit, &pm).unwrap();
    assert!(a.faults.is_none(), "no plan, no ledger");
    let aj = a.to_json().to_string_pretty();
    assert_eq!(aj, b.to_json().to_string_pretty());
    assert_eq!(a.render_table(), b.render_table());
    assert!(!aj.contains("\"faults\""), "healthy JSON has no faults key");
    assert!(!aj.contains("\"replica_scales\""));
    assert!(!a.render_table().contains("faults:"));
}

#[test]
fn crafted_crash_and_drain_conserve_and_price_downtime_exactly() {
    let pm = PowerModel::paper();
    let models = bottleneck_fleet(3, 250.0);
    let scfg = ServeConfig {
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    let h = horizon_cy(&scfg);

    // healthy baseline pins the offered load and finds the busy node
    let healthy = simulate_fleet(&models, &scfg, &FleetConfig::new(3, RouterPolicy::Hash), &pm)
        .unwrap();
    let offered = healthy.total_arrivals();
    assert!(offered > 0);
    let node_arr = |rep: &imcc::serve::FleetReport, k: usize| -> u64 {
        rep.nodes[k]
            .report
            .tenants
            .iter()
            .map(|t| t.arrivals)
            .sum()
    };
    let busy = (0..3).max_by_key(|&k| (node_arr(&healthy, k), k)).unwrap();
    assert!(node_arr(&healthy, busy) > 0);
    let other = (busy + 1) % 3;

    // crash the busy node a quarter in, recover at the half; drain
    // another node at 5/8 with no rejoin
    let (t1, t2, t3) = (h / 4, h / 2, h * 5 / 8);
    let spec = format!("crash@node{busy}:{t1}..{t2},drain@node{other}:{t3}");
    let mut fcfg = FleetConfig::new(3, RouterPolicy::Hash);
    fcfg.faults = FaultPlan::parse(&spec).unwrap();
    let rep = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
    let fo = rep.faults.as_ref().expect("armed plan reports a ledger");

    // extended conservation: the ledger travels with every request
    assert_eq!(rep.total_arrivals(), offered - fo.lost_in_crash);
    assert_eq!(
        rep.total_served() + rep.total_dropped() + rep.total_rejected(),
        rep.total_arrivals()
    );
    // every retried request accounted exactly once
    let moved: u64 = fo.failovers.iter().map(|f| f.moved as u64).sum();
    assert_eq!(fo.retried, moved);
    // survivor hand-offs pay the hand-off DMA price; rejoins don't
    for f in &fo.failovers {
        if f.rejoin {
            assert_eq!(f.from_node, f.to_node);
            assert_eq!(f.handoff_cycles, 0);
        } else {
            assert_ne!(f.from_node, f.to_node);
            assert_eq!(
                f.handoff_cycles,
                f.moved as u64 * fcfg.migration.handoff_cy_per_req
            );
        }
    }
    // downtime to the cycle: the crash span closes at recovery, the
    // drain span runs to the horizon
    assert_eq!(fo.downtime_cy[busy], t2 - t1);
    assert_eq!(fo.downtime_cy[other], h - t3);
    let third = 3 - busy - other;
    assert_eq!(fo.downtime_cy[third], 0);
    assert!(fo.availability() < 1.0);
    let expect_avail = 1.0 - ((t2 - t1) + (h - t3)) as f64 / (3.0 * h as f64);
    assert!((fo.availability() - expect_avail).abs() < 1e-12);
    // the rendered artifacts carry the chaos sections deterministically
    let again = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
    assert_eq!(rep.render_table(), again.render_table());
    assert_eq!(
        rep.to_json().to_string_pretty(),
        again.to_json().to_string_pretty()
    );
    assert!(rep.render_table().contains("faults:"));
    assert!(rep.to_json().to_string_pretty().contains("\"availability\""));
}

#[test]
fn seeded_fault_plans_are_deterministic_and_conserve() {
    let pm = PowerModel::paper();
    let models = mnv2_bottleneck_pair(150.0);
    let scfg = ServeConfig {
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    let h = horizon_cy(&scfg);
    let offered = simulate_fleet(&models, &scfg, &FleetConfig::new(3, RouterPolicy::Hash), &pm)
        .unwrap()
        .total_arrivals();

    // property over seeds: every drawn plan validates, runs, conserves,
    // and reproduces byte-for-byte
    let mut fired = 0;
    for seed in [0x1u64, 0xBEEF, 0xC0FFEE, 77, 0xFEED_FACE] {
        let plan = FaultPlan::seeded(seed, 3, h, h / 3);
        plan.validate(3, &[64, 64, 64]).expect("seeded plans validate");
        let mut fcfg = FleetConfig::new(3, RouterPolicy::Hash);
        fcfg.faults = plan.clone();
        if plan.is_empty() {
            continue; // a long-MTBF draw can be fault-free
        }
        let rep = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
        let fo = rep.faults.as_ref().unwrap();
        assert_eq!(rep.total_arrivals(), offered - fo.lost_in_crash, "seed {seed:#x}");
        assert_eq!(
            rep.total_served() + rep.total_dropped() + rep.total_rejected(),
            rep.total_arrivals(),
            "seed {seed:#x}"
        );
        let moved: u64 = fo.failovers.iter().map(|f| f.moved as u64).sum();
        assert_eq!(fo.retried, moved, "seed {seed:#x}");
        assert!(fo.availability() <= 1.0);
        if fo.events.iter().any(|e| e.label == "crash" && e.t < h) {
            fired += 1;
            assert!(
                fo.availability() < 1.0,
                "seed {seed:#x}: a crash inside the horizon must cost availability"
            );
        }
        // node 0 is the seeded plan's survivor anchor
        assert!(fo.events.iter().all(|e| e.node != 0), "seed {seed:#x}");
        let again = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
        assert_eq!(
            rep.to_json().to_string_pretty(),
            again.to_json().to_string_pretty(),
            "seed {seed:#x}"
        );
    }
    assert!(fired > 0, "an mtbf of a third of the horizon draws real crashes");
}

#[test]
fn rolling_update_touches_every_node_and_loses_nothing() {
    let pm = PowerModel::paper();
    let models = mnv2_bottleneck_pair(150.0);
    let scfg = ServeConfig {
        duration_s: 0.02,
        ..ServeConfig::default()
    };
    let h = horizon_cy(&scfg);
    let offered = simulate_fleet(
        &models,
        &scfg,
        &FleetConfig::new(3, RouterPolicy::Replica),
        &pm,
    )
    .unwrap()
    .total_arrivals();

    let down = h / 16;
    let plan = FaultPlan::rolling_update(3, h / 4, down);
    plan.validate(3, &[64, 64, 64]).expect("staggered wave validates");
    let mut fcfg = FleetConfig::new(3, RouterPolicy::Replica);
    fcfg.faults = plan;
    let rep = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
    let fo = rep.faults.as_ref().unwrap();

    // a drain completes in-flight work and fails over the queue: zero loss
    assert_eq!(fo.lost_in_crash, 0);
    assert_eq!(rep.total_arrivals(), offered);
    assert_eq!(
        rep.total_served() + rep.total_dropped() + rep.total_rejected(),
        offered
    );
    // every node went down exactly once, for exactly the update window
    assert_eq!(fo.events.len(), 6, "3 update drains + 3 rejoins");
    assert_eq!(fo.events.iter().filter(|e| e.label == "update").count(), 3);
    assert_eq!(fo.events.iter().filter(|e| e.label == "rejoin").count(), 3);
    for node in 0..3 {
        assert_eq!(fo.downtime_cy[node], down, "node{node}");
    }
    assert!(fo.availability() < 1.0);
    let moved: u64 = fo.failovers.iter().map(|f| f.moved as u64).sum();
    assert_eq!(fo.retried, moved);
    // determinism of the whole wave
    let again = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
    assert_eq!(
        rep.to_json().to_string_pretty(),
        again.to_json().to_string_pretty()
    );
}

#[test]
fn fleet_autoscale_grows_exactly_once_after_a_sustained_burst() {
    let pm = PowerModel::paper();
    // two small nodes, one heavily overloaded tenant: backlog builds on
    // the ring owner until the fleet controller activates the second
    // replica; the huge cooldown pins the controller to one action
    let models = hot_mnv2(10_000.0);
    let scfg = ServeConfig {
        n_arrays: 12,
        duration_s: 0.02,
        autoscale: true,
        autoscale_cfg: AutoscaleConfig {
            hi_depth: 2,
            lo_depth: 0,
            window_cy: 100_000,
            cooldown_cy: 1_000_000_000_000,
        },
        ..ServeConfig::default()
    };
    let mut fcfg = FleetConfig::new(2, RouterPolicy::Replica);
    fcfg.node_arrays = vec![12, 12];
    let rep = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
    assert_eq!(
        rep.replica_scales.len(),
        1,
        "one grow, then the cooldown (and the exhausted pool) hold"
    );
    let s = &rep.replica_scales[0];
    assert!(s.grow);
    assert_eq!(s.active_after, 2, "both replicas active after the grow");
    // the re-shard really moved pending work onto the second replica
    assert!(s.moved > 0);
    let per_node: Vec<u64> = rep
        .nodes
        .iter()
        .map(|n| n.report.tenants.iter().map(|t| t.arrivals).sum())
        .collect();
    assert!(
        per_node.iter().filter(|&&a| a > 0).count() == 2,
        "both nodes ended up owning traffic: {per_node:?}"
    );
    // conservation and the gated JSON section
    assert_eq!(
        rep.total_served() + rep.total_dropped() + rep.total_rejected(),
        rep.total_arrivals()
    );
    let js = rep.to_json().to_string_pretty();
    assert!(js.contains("\"replica_scales\""));
    assert!(rep.faults.is_none(), "autoscaling is not a fault");
    // determinism
    let again = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
    assert_eq!(js, again.to_json().to_string_pretty());
    assert_eq!(rep.render_table(), again.render_table());
}

#[test]
fn fault_plan_grammar_round_trips_and_rejects_nonsense() {
    // grammar → plan → describe echo parses back to the same plan
    let spec =
        "crash@node1:5e6..8e6,drain@node2:1e7,degrade@node1:2e6..9e6x1.5,arrayfail@node0:3e6x2";
    let plan = FaultPlan::parse(spec).unwrap();
    let echo = plan.describe();
    let replay = FaultPlan::parse(&echo).unwrap();
    assert_eq!(plan, replay, "describe() is a faithful replay spec");
    // malformed specs name the problem
    for bad in [
        "crash@node1",            // no instant
        "crash@1:5e6",            // node prefix missing
        "explode@node1:5e6",      // unknown kind
        "crash@node1:5e6x2",      // crash takes no factor
        "update@node1:5e6",       // update needs a rejoin instant
        "degrade@node1:5e6..6e6", // degrade needs a factor
        "",                       // empty
    ] {
        assert!(FaultPlan::parse(bad).is_err(), "`{bad}` must not parse");
    }
    // validation catches fleet-shape mistakes the grammar can't
    let p = FaultPlan::parse("crash@node3:1e6").unwrap();
    assert!(p.validate(3, &[64, 64, 64]).is_err(), "node out of range");
    let p = FaultPlan::parse("crash@node1:2e6..1e6");
    assert!(p.is_err() || p.unwrap().validate(3, &[64, 64, 64]).is_err());
    let p = FaultPlan::parse("arrayfail@node1:1e6x64").unwrap();
    assert!(
        p.validate(3, &[64, 64, 64]).is_err(),
        "failing every array leaves no node"
    );
    let p = FaultPlan::parse("crash@node1:1e6..3e6,crash@node1:2e6..4e6").unwrap();
    assert!(
        p.validate(3, &[64, 64, 64]).is_err(),
        "overlapping down-spans"
    );
}
