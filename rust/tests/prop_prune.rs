//! Property tests for watermark pruning (no artifacts needed).
//!
//! The pruning contract: folding committed intervals behind the oldest
//! possible future dispatch is **invisible** to everything a serving run
//! reports — dispatch tables, makespans, busy-cycle unions, peak
//! backlogs, per-tenant percentiles — while strictly shrinking the
//! gap-search state on long runs. Checked over random Poisson and MMPP-2
//! backlogs, random fleet sizes, and both dispatch disciplines; plus a
//! unit check that a long run really does drop interval nodes.

use imcc::arch::PowerModel;
use imcc::serve::{simulate, ModelTraffic, ServeConfig, ServeReport, TrafficModel};
use imcc::util::prop;
use imcc::util::rng::SplitMix64;

/// `n` bottleneck tenants with one random traffic model each.
fn random_fleet(rng: &mut SplitMix64, n: usize) -> Vec<ModelTraffic> {
    (0..n)
        .map(|i| {
            let mut net = imcc::net::bottleneck::bottleneck();
            net.name = format!("bn-{i}");
            let rate_per_s = 50.0 + rng.next_f64() * 350.0;
            let traffic = if rng.below(2) == 1 {
                TrafficModel::Bursty {
                    rate_per_s,
                    burst: 2.0 + rng.next_f64() * 4.0,
                    dwell_s: 0.002 + rng.next_f64() * 0.01,
                }
            } else {
                TrafficModel::Poisson { rate_per_s }
            };
            ModelTraffic {
                net,
                traffic,
                weight: 1,
            }
        })
        .collect()
}

/// Everything the dispatch table derives from must be bit-identical.
fn assert_reports_identical(p: &ServeReport, u: &ServeReport, ctx: &str) {
    assert_eq!(p.render_table(), u.render_table(), "{ctx}: dispatch tables");
    assert_eq!(p.makespan_cycles, u.makespan_cycles, "{ctx}: makespan");
    assert_eq!(p.busy_cycles, u.busy_cycles, "{ctx}: busy-cycle union");
    assert_eq!(p.peak_backlog, u.peak_backlog, "{ctx}: peak backlog");
    assert_eq!(p.counters.steps, u.counters.steps, "{ctx}: event-loop steps");
    assert_eq!(p.counters.validations, u.counters.validations, "{ctx}: validations");
    for (x, y) in p.tenants.iter().zip(u.tenants.iter()) {
        assert_eq!(x.latency.percentiles(), y.latency.percentiles(), "{ctx}: {}", x.name);
        assert_eq!(
            (x.served, x.dropped, x.batches, x.busy_cycles),
            (y.served, y.dropped, y.batches, y.busy_cycles),
            "{ctx}: {}",
            x.name
        );
        assert_eq!(x.peak_queue, y.peak_queue, "{ctx}: {}", x.name);
    }
    // the busy-interval union history feeds the utilization breakdown —
    // pruning must not forget a cycle of it
    for (a, b) in p.resource_busy.iter().zip(u.resource_busy.iter()) {
        assert_eq!(a.name, b.name, "{ctx}");
        assert_eq!(a.busy_cycles, b.busy_cycles, "{ctx}: {}", a.name);
    }
}

#[test]
fn pruned_and_unpruned_serves_are_bit_identical_on_random_backlogs() {
    prop::check("prune_bit_identity", 10, |rng: &mut SplitMix64| {
        let pm = PowerModel::paper();
        let n = rng.range_i64(1, 4) as usize;
        let models = random_fleet(rng, n);
        let backfill = rng.below(2) == 1;
        let base = ServeConfig {
            n_arrays: 6 * n,
            backfill,
            seed: rng.next_u64(),
            duration_s: 0.02 + rng.next_f64() * 0.03,
            deadline_cy: [0u64, 2_000_000][rng.below(2) as usize],
            ..ServeConfig::default()
        };
        assert!(base.prune, "pruning is the default");
        let pruned = simulate(&models, &base, &pm).unwrap();
        let unpruned = simulate(
            &models,
            &ServeConfig {
                prune: false,
                ..base.clone()
            },
            &pm,
        )
        .unwrap();
        let ctx = format!("n {n}, backfill {backfill}, seed {:#x}", base.seed);
        assert!(pruned.prune && !unpruned.prune);
        assert_reports_identical(&pruned, &unpruned, &ctx);
        // pruning only ever shrinks the search state
        let (pc, uc) = (pruned.counters, unpruned.counters);
        assert!(pc.live_intervals <= uc.live_intervals, "{ctx}: live");
        assert!(pc.probes <= uc.probes, "{ctx}: probe work");
        assert_eq!(uc.pruned_intervals, 0, "{ctx}");
        assert_eq!(uc.watermark, 0, "{ctx}");
    });
}

#[test]
fn long_run_pruning_strictly_drops_interval_nodes() {
    // the unit pin: on a long multi-tenant run the pruned timeline holds
    // strictly fewer live interval nodes (and did fold some away), at a
    // bit-identical dispatch table
    let pm = PowerModel::paper();
    let models = imcc::serve::bottleneck_fleet(4, 150.0);
    let base = ServeConfig {
        n_arrays: 24,
        duration_s: 0.25,
        ..ServeConfig::default()
    };
    let pruned = simulate(&models, &base, &pm).unwrap();
    let unpruned = simulate(
        &models,
        &ServeConfig {
            prune: false,
            ..base
        },
        &pm,
    )
    .unwrap();
    assert_reports_identical(&pruned, &unpruned, "long run");
    let (pc, uc) = (pruned.counters, unpruned.counters);
    assert!(pc.pruned_intervals > 0, "a long run must fold intervals away");
    assert!(
        pc.live_intervals < uc.live_intervals,
        "live nodes {} !< {}",
        pc.live_intervals,
        uc.live_intervals
    );
    assert!(pc.watermark > 0);
    // peak footprint shrinks too: the live window never holds the whole
    // history
    assert!(
        pc.peak_live_intervals < uc.peak_live_intervals,
        "peak {} !< {}",
        pc.peak_live_intervals,
        uc.peak_live_intervals
    );
}
