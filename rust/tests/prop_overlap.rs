//! Property tests for the per-resource contention model (no artifacts
//! needed).
//!
//! Conservation properties of reservation profiles and overlapped
//! dispatch: per-resource busy time fits inside its envelope and the
//! batch makespan; overlapped serving never exceeds the serialized sum
//! (and strictly beats it whenever two tenants share a pool); streamed
//! weight updates never lose to the blocking barrier; and strict mode
//! (`overlap: false`, 1-wide window, no pipelining) stays bit-identical
//! to the scheduler's honest sequential baseline on resident and staged
//! tenants.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::timeline::{N_CORES, RES_ARRAY0, RES_CORE0};
use imcc::coordinator::{run_batched, BatchConfig, PlanCache, Strategy};
use imcc::net::bottleneck::bottleneck;
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::serve::{simulate, BatchWindow, ModelTraffic, ServeConfig, TrafficModel};
use imcc::util::prop;
use imcc::util::rng::SplitMix64;

#[test]
fn batch_profile_conservation_on_random_configs() {
    prop::check("batch_profile_conservation", 16, |rng: &mut SplitMix64| {
        let pm = PowerModel::paper();
        let staged = rng.below(2) == 1;
        let net = if staged { mobilenet_v2(224) } else { bottleneck() };
        let cfg = SystemConfig::scaled_up(8);
        let mut cache = PlanCache::new();
        let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
        let cfgb = BatchConfig {
            batch: rng.range_i64(1, 7) as usize,
            pipeline: rng.below(2) == 1,
            charge_dma: true,
            stream_weights: rng.below(2) == 1,
        };
        let rep = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, cfgb);

        // per-resource busy ≤ envelope ≤ makespan, interval sets canonical
        assert_eq!(rep.profile.len, rep.cycles);
        assert!(!rep.profile.spans.is_empty());
        for s in &rep.profile.spans {
            assert!(s.first_use <= s.last_release, "res {}", s.res);
            assert!(s.last_release <= rep.profile.len, "res {}", s.res);
            assert!(s.busy <= s.last_release - s.first_use, "res {}", s.res);
            if s.res >= RES_ARRAY0 {
                assert!(s.res - RES_ARRAY0 < plan.n_arrays);
            }
            // intervals: sorted, disjoint, non-adjacent, bracketing the
            // envelope, summing exactly to the busy cycles
            assert!(!s.intervals.is_empty(), "res {}", s.res);
            for w in s.intervals.windows(2) {
                assert!(w[0].1 < w[1].0, "res {}: {:?}", s.res, s.intervals);
            }
            assert_eq!(s.intervals.first().map(|&(a, _)| a), Some(s.first_use));
            assert_eq!(s.intervals.last().map(|&(_, b)| b), Some(s.last_release));
            let total: u64 = s.intervals.iter().map(|&(a, b)| b - a).sum();
            assert_eq!(total, s.busy, "res {}", s.res);
        }
        // per-core prefix: core 0 carries every core layer, so it
        // dominates every other core's envelope — the precondition that
        // makes envelope dispatch equivalent to the PR 3 fused complex
        if let Some(c0) = rep.profile.span(RES_CORE0) {
            for c in 1..N_CORES {
                if let Some(s) = rep.profile.span(RES_CORE0 + c) {
                    assert!(s.first_use >= c0.first_use, "core{c}");
                    assert!(s.last_release <= c0.last_release, "core{c}");
                    assert!(s.busy <= c0.busy, "core{c}");
                }
            }
        } else {
            for c in 1..N_CORES {
                assert!(rep.profile.span(RES_CORE0 + c).is_none(), "core{c}");
            }
        }
        // never faster than one request, never slower than the honest
        // sequential baseline
        assert!(rep.cycles >= rep.per_request_cycles);
        assert!(rep.cycles <= rep.sequential_cycles);

        // streaming relaxes constraints only: same work, ≤ makespan
        if cfgb.stream_weights {
            let block = run_batched(
                &net,
                Strategy::ImaDw,
                &cfg,
                &pm,
                &plan,
                BatchConfig {
                    stream_weights: false,
                    ..cfgb
                },
            );
            assert!(rep.cycles <= block.cycles);
            assert_eq!(rep.reprogram_cycles, block.reprogram_cycles);
            assert_eq!(rep.dma_cycles, block.dma_cycles);
            assert_eq!(rep.sequential_cycles, block.sequential_cycles);
        }
    });
}

#[test]
fn overlap_conservation_on_t0_backlogs() {
    prop::check("overlap_conservation", 10, |rng: &mut SplitMix64| {
        let pm = PowerModel::paper();
        let n_models = rng.range_i64(1, 4) as usize;
        let n_req = rng.range_i64(1, 13) as usize;
        let max_batch = rng.range_i64(1, 7) as usize;
        let pipeline = rng.below(2) == 1;
        let models: Vec<ModelTraffic> = (0..n_models)
            .map(|i| {
                let mut net = bottleneck();
                net.name = format!("bn-{i}");
                ModelTraffic {
                    net,
                    traffic: TrafficModel::Trace {
                        arrivals_cy: vec![0; n_req],
                    },
                    weight: 1,
                }
            })
            .collect();
        let base = ServeConfig {
            n_arrays: 8 * n_models,
            window: BatchWindow {
                max_batch,
                max_wait_cy: 0,
            },
            pipeline,
            duration_s: 0.01,
            ..ServeConfig::default()
        };
        let on = simulate(&models, &base, &pm).unwrap();
        let off = simulate(
            &models,
            &ServeConfig {
                overlap: false,
                ..base
            },
            &pm,
        )
        .unwrap();

        // identical work either way
        assert_eq!(on.total_served(), (n_models * n_req) as u64);
        assert_eq!(off.total_served(), on.total_served());

        // the serialized pool is back-to-back: makespan = batch-span sum;
        // overlapped makespan ≤ that sum, strictly < with several tenants
        let sum: u64 = off.tenants.iter().map(|t| t.busy_cycles).sum();
        assert_eq!(off.makespan_cycles, sum);
        assert!(
            on.makespan_cycles <= off.makespan_cycles,
            "n_models {n_models} n_req {n_req} max_batch {max_batch}"
        );
        if n_models > 1 {
            assert!(on.makespan_cycles < off.makespan_cycles);
        }

        // conservation: busy union and every per-resource busy fit the
        // makespan
        assert!(on.busy_cycles <= on.makespan_cycles);
        for r in &on.resource_busy {
            let u = on.resource_utilization(r);
            assert!((0.0..=1.0).contains(&u), "{} at {u}", r.name);
        }
    });
}

#[test]
fn strict_mode_equals_sequential_baseline_on_random_backlogs() {
    // `--no-overlap` + 1-wide window + no pipelining is the PR 2
    // serialized baseline, bit-identical on resident and staged tenants
    prop::check("strict_serialized_baseline", 8, |rng: &mut SplitMix64| {
        let pm = PowerModel::paper();
        let n = rng.range_i64(1, 7) as usize;
        let staged = rng.below(2) == 1;
        let net = if staged { mobilenet_v2(224) } else { bottleneck() };
        let models = vec![ModelTraffic {
            net: net.clone(),
            traffic: TrafficModel::Trace {
                arrivals_cy: vec![0; n],
            },
            weight: 1,
        }];
        let scfg = ServeConfig {
            n_arrays: 8,
            window: BatchWindow {
                max_batch: 1,
                max_wait_cy: 0,
            },
            pipeline: false,
            overlap: false,
            duration_s: 0.01,
            ..ServeConfig::default()
        };
        let rep = simulate(&models, &scfg, &pm).unwrap();
        assert_eq!(rep.tenants[0].served, n as u64);

        let cfg = SystemConfig::scaled_up(8);
        let mut cache = PlanCache::new();
        let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
        let strict = run_batched(
            &net,
            Strategy::ImaDw,
            &cfg,
            &pm,
            &plan,
            BatchConfig {
                batch: n,
                pipeline: false,
                ..BatchConfig::default()
            },
        );
        assert_eq!(
            rep.makespan_cycles,
            strict.sequential_cycles,
            "staged {staged}, n {n}"
        );
    });
}
