//! Property tests across the job-backend boundary (no artifacts needed —
//! the native backend implements the AOT numeric contract directly).
//!
//! The golden tests pin two fixed networks; these pit the Rust-orchestrated
//! job path against an independent host-side integer reference on *random*
//! layer shapes — catching orchestration bugs (tiling, padding, chunking,
//! accumulation order) the fixed goldens might miss.

use imcc::runtime::client::XBAR;
use imcc::runtime::Runtime;
use imcc::util::prop;
use imcc::util::rng::SplitMix64;

fn artifacts_dir() -> String {
    std::env::var("IMCC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Host reference of the numeric contract (DESIGN.md §4) for one linear
/// layer: acc = x·w (int32), round-shift, optional relu, clip.
fn host_linear(x: &[i8], w: &[i8], rows: usize, cols: usize, n_px: usize, shift: i32, relu: bool) -> Vec<i8> {
    let mut out = vec![0i8; n_px * cols];
    for p in 0..n_px {
        for c in 0..cols {
            let mut acc: i64 = 0;
            for r in 0..rows {
                acc += x[p * rows + r] as i64 * w[r * cols + c] as i64;
            }
            let mut v = if shift > 0 {
                (acc + (1i64 << (shift - 1))) >> shift
            } else {
                acc
            };
            if relu {
                v = v.max(0);
            }
            out[p * cols + c] = v.clamp(-128, 127) as i8;
        }
    }
    out
}

#[test]
fn random_linear_layers_match_host_reference() {
    let mut rt = Runtime::load(&artifacts_dir()).unwrap();
    // pre-generate cases (program_weight_tile needs &mut; prop::check takes Fn)
    let mut cases = Vec::new();
    let mut rng = SplitMix64::new(0xFEED);
    for case in 0..12 {
        let rows = rng.range_i64(1, 256) as usize;
        let cols = rng.range_i64(1, 256) as usize;
        let shift = rng.range_i64(0, 14) as i32;
        let relu = rng.below(2) == 1;
        let mut x = vec![0i8; 16 * rows];
        rng.fill_i8(&mut x);
        let mut w = vec![0i8; rows * cols];
        rng.fill_i4(&mut w);
        // pad to the crossbar tile
        let mut xp = vec![0i8; 16 * XBAR];
        for p in 0..16 {
            xp[p * XBAR..p * XBAR + rows].copy_from_slice(&x[p * rows..(p + 1) * rows]);
        }
        let mut wp = vec![0i8; XBAR * XBAR];
        for r in 0..rows {
            wp[r * XBAR..r * XBAR + cols].copy_from_slice(&w[r * cols..(r + 1) * cols]);
        }
        let key = (10_000 + case, 0, 0);
        rt.program_weight_tile(key, &wp).unwrap();
        cases.push((key, xp, x, w, rows, cols, shift, relu));
    }
    for (key, xp, x, w, rows, cols, shift, relu) in &cases {
        let y = rt.mvm(*key, xp, *shift, *relu, 16).unwrap();
        let want = host_linear(x, w, *rows, *cols, 16, *shift, *relu);
        for p in 0..16 {
            for c in 0..*cols {
                assert_eq!(
                    y[p * XBAR + c],
                    want[p * cols + c],
                    "key {key:?} rows {rows} cols {cols} shift {shift} relu {relu} p {p} c {c}"
                );
            }
        }
        // raw + host requant must equal the fused path
        let raw = rt.mvm_raw(*key, xp, 16).unwrap();
        let rq = rt.requant(&raw, *shift, *relu, 16).unwrap();
        assert_eq!(&rq[..], &y[..], "raw+requant != fused for {key:?}");
    }
}

#[test]
fn batched_128px_equals_eight_16px_calls() {
    let mut rt = Runtime::load(&artifacts_dir()).unwrap();
    let mut rng = SplitMix64::new(0xBEEF);
    let mut w = vec![0i8; XBAR * XBAR];
    rng.fill_i4(&mut w);
    let key = (20_000, 0, 0);
    rt.program_weight_tile(key, &w).unwrap();
    let mut x = vec![0i8; 128 * XBAR];
    rng.fill_i8(&mut x);

    let big = rt.mvm(key, &x, 7, true, 128).unwrap();
    for chunk in 0..8 {
        let lo = chunk * 16 * XBAR;
        let small = rt.mvm(key, &x[lo..lo + 16 * XBAR], 7, true, 16).unwrap();
        assert_eq!(&big[lo..lo + 16 * XBAR], &small[..], "chunk {chunk}");
    }
}

#[test]
fn dw_tile_matches_host_reference_random() {
    let rt = Runtime::load(&artifacts_dir()).unwrap();
    prop::check("dw_host_ref", 8, |rng| {
        let stride = 1 + rng.below(2) as usize;
        let side = (16 - 1) * stride + 3;
        let mut x = vec![0i8; side * side * 16];
        rng.fill_i8(&mut x);
        let mut w = vec![0i8; 9 * 16];
        rng.fill_i4(&mut w);
        let shift = rng.range_i64(0, 10) as i32;
        let y = rt.dw_tile(&x, &w, shift, true, stride).unwrap();
        for ty in 0..16usize {
            for tx in 0..16usize {
                for ch in 0..16usize {
                    let mut acc: i64 = 0;
                    for ki in 0..3usize {
                        for kj in 0..3usize {
                            let sy = ty * stride + ki;
                            let sx = tx * stride + kj;
                            acc += x[(sy * side + sx) * 16 + ch] as i64
                                * w[(ki * 3 + kj) * 16 + ch] as i64;
                        }
                    }
                    let mut v = if shift > 0 {
                        (acc + (1i64 << (shift - 1))) >> shift
                    } else {
                        acc
                    };
                    v = v.max(0).min(127);
                    assert_eq!(
                        y[(ty * 16 + tx) * 16 + ch],
                        v as i8,
                        "stride {stride} ty {ty} tx {tx} ch {ch}"
                    );
                }
            }
        }
    });
}
