//! Bench: remaining exhibits (Fig. 6b area, baselines) + report plumbing
//! (table rendering, JSON round-trip) — cheap but tracked so regressions in
//! the reporting layer are visible.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::baselines::{all_baselines, Vega};
use imcc::report::fig6_area;
use imcc::util::bench::bench;
use imcc::util::json::Json;

fn main() {
    println!("== bench_reports (Fig. 6b / Table I baselines) ==");
    let cfg = SystemConfig::paper();
    let pm = PowerModel::paper();
    let _ = pm;

    bench("fig6_area", 100, 300, || fig6_area::generate(&cfg));
    bench("vega_baseline_model", 10, 1500, || Vega::default().mnv2());
    bench("all_baseline_rows", 10, 1500, || {
        all_baselines().iter().map(|b| b.row()).count()
    });

    let rep = fig6_area::generate(&cfg);
    bench("json_roundtrip_report", 200, 300, || {
        Json::parse(&rep.data.to_string_pretty()).unwrap()
    });

    println!("result:\n{}", rep.text);
}
