//! Bench: Alg. 1 TILE&PACK — packing quality and packer throughput
//! (MaxRects-BSSF is O(tiles × bins × free-rects); this tracks the constant).

use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::tilepack::{pack, tile_network, Tile};
use imcc::util::bench::bench;
use imcc::util::rng::SplitMix64;

fn synthetic_tiles(n: usize, seed: u64) -> Vec<Tile> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|i| Tile {
            layer: i,
            name: format!("t{i}"),
            row0: 0,
            col0: 0,
            rows: rng.range_i64(8, 256) as usize,
            cols: rng.range_i64(8, 256) as usize,
        })
        .collect()
}

fn main() {
    println!("== bench_tilepack (Alg. 1 / Fig. 12b) ==");
    let net = mobilenet_v2(224);
    let tiles = tile_network(&net, 256);

    bench("tile_mobilenetv2", 100, 300, || tile_network(&net, 256));
    bench("pack_mobilenetv2", 20, 1000, || pack(&tiles, 256, false));
    bench("pack_mobilenetv2_rotate", 20, 1000, || pack(&tiles, 256, true));

    for n in [100usize, 400, 1600] {
        let synth = synthetic_tiles(n, 42);
        bench(&format!("pack_synthetic_{n}"), 5, 1500, || {
            pack(&synth, 256, false)
        });
    }

    let p = pack(&tiles, 256, false);
    println!(
        "result: {} crossbars for MobileNetV2 (paper: 34), min util {:.0}%",
        p.n_bins(),
        p.utilizations().iter().cloned().fold(f64::INFINITY, f64::min) * 100.0
    );
}
