//! Bench: Fig. 9/10 Bottleneck case study — regenerates the paper rows and
//! times the per-strategy simulation cost.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::{run_network, Strategy};
use imcc::net::bottleneck::bottleneck;
use imcc::report::{fig10_breakdown, fig9_bottleneck};
use imcc::util::bench::bench;

fn main() {
    println!("== bench_bottleneck (Fig. 9 / Fig. 10) ==");
    let cfg = SystemConfig::paper();
    let pm = PowerModel::paper();
    let net = bottleneck();

    for s in Strategy::paper_lineup() {
        bench(&format!("simulate_{}", s.label()), 100, 300, || {
            run_network(&net, s, &cfg, &pm)
        });
    }
    bench("fig9_full", 20, 500, || fig9_bottleneck::generate(&cfg, &pm));
    bench("fig10_full", 20, 500, || fig10_breakdown::generate(&cfg, &pm));

    // the experiment rows (cargo bench log carries the reproduction)
    let rep = fig9_bottleneck::generate(&cfg, &pm);
    println!("{}", rep.text);
}
