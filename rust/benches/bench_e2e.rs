//! Bench: Fig. 12 / Table I end-to-end MobileNetV2 — regenerates the
//! headline result and times the whole-network simulation.

use imcc::arch::PowerModel;
use imcc::report::{fig12_e2e, fig13_models, table1};
use imcc::util::bench::bench;

fn main() {
    println!("== bench_e2e (Fig. 12 / Table I / Fig. 13) ==");
    let pm = PowerModel::paper();

    bench("e2e_config_and_pack", 10, 1000, fig12_e2e::e2e_config);
    let (cfg, _) = fig12_e2e::e2e_config();
    bench("e2e_simulate_64_layers", 10, 1000, || {
        fig12_e2e::run(&cfg, &pm)
    });
    bench("fig12_full_report", 5, 2000, || fig12_e2e::generate(&pm));
    bench("table1_full", 5, 2000, || table1::generate(&pm));
    bench("fig13_full", 5, 2000, || fig13_models::generate(&pm));

    let rep = fig12_e2e::generate(&pm);
    println!(
        "result: {:.2} ms, {:.0} µJ, {:.0} inf/s (paper: 10.1 ms, 482 µJ, 99 inf/s)",
        rep.data.req("total_time_s").as_f64().unwrap() * 1e3,
        rep.data.req("total_energy_j").as_f64().unwrap() * 1e6,
        rep.data.req("inf_per_s").as_f64().unwrap()
    );
}
