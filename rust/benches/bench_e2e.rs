//! Bench: Fig. 12 / Table I end-to-end MobileNetV2 — regenerates the
//! headline result, times the whole-network simulation, and measures the
//! multi-array serving loop: batched model throughput (inferences/s) vs
//! batch size, plus the wall cost of a plan-cache hit vs a cold placement.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::{run_batched, BatchConfig, PlanCache, Strategy};
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::report::{fig12_e2e, fig13_models, table1};
use imcc::util::bench::bench;

fn main() {
    println!("== bench_e2e (Fig. 12 / Table I / Fig. 13 / scale-up serving) ==");
    let pm = PowerModel::paper();

    bench("e2e_config_and_pack", 10, 1000, fig12_e2e::e2e_config);
    let (cfg, _) = fig12_e2e::e2e_config();
    bench("e2e_simulate_64_layers", 10, 1000, || {
        fig12_e2e::run(&cfg, &pm)
    });
    bench("fig12_full_report", 5, 2000, || fig12_e2e::generate(&pm));
    bench("table1_full", 5, 2000, || table1::generate(&pm));
    bench("fig13_full", 5, 2000, || fig13_models::generate(&pm));

    let rep = fig12_e2e::generate(&pm);
    println!(
        "result: {:.2} ms, {:.0} µJ, {:.0} inf/s (paper: 10.1 ms, 482 µJ, 99 inf/s)",
        rep.data.req("total_time_s").as_f64().unwrap() * 1e3,
        rep.data.req("total_energy_j").as_f64().unwrap() * 1e6,
        rep.data.req("inf_per_s").as_f64().unwrap()
    );

    // ---- batched serving: model throughput vs batch size -----------------
    let net = mobilenet_v2(224);
    let arrays = 40usize;
    let cfg40 = SystemConfig::scaled_up(arrays);
    let mut cache = PlanCache::new();

    bench("placement_cold (cache miss)", 5, 2000, || {
        imcc::tilepack::place_staged(&net, 256, arrays, false).unwrap()
    });
    let plan = cache.get_or_place(&net, 256, arrays, false).unwrap();
    bench("placement_hot (cache hit)", 50, 500, || {
        cache.get_or_place(&net, 256, arrays, false).unwrap()
    });

    println!("\nbatched throughput, {arrays}-array resident pool (model inf/s):");
    let mut b1 = 0.0f64;
    for batch in [1usize, 2, 4, 8, 16] {
        let r = run_batched(
            &net,
            Strategy::ImaDw,
            &cfg40,
            &pm,
            &plan,
            BatchConfig {
                batch,
                pipeline: true,
                ..BatchConfig::default()
            },
        );
        if batch == 1 {
            b1 = r.inferences_per_s();
        }
        println!(
            "  batch {batch:>2}: {:>7.1} inf/s  ({:.2}x vs batch 1, bottleneck `{}`)",
            r.inferences_per_s(),
            r.inferences_per_s() / b1,
            r.bottleneck_layer
        );
        bench(&format!("run_batched_b{batch}"), 10, 500, || {
            run_batched(
                &net,
                Strategy::ImaDw,
                &cfg40,
                &pm,
                &plan,
                BatchConfig {
                    batch,
                    pipeline: true,
                    ..BatchConfig::default()
                },
            )
        });
    }

    // ---- staged pool: PCM weight-update streaming ------------------------
    let cfg8 = SystemConfig::scaled_up(8);
    let plan8 = cache.get_or_place(&net, 256, 8, false).unwrap();
    println!("\nstaged 8-array pool, weight-update streaming (model inf/s):");
    for batch in [1usize, 4, 8] {
        let mk = |stream_weights: bool| {
            run_batched(
                &net,
                Strategy::ImaDw,
                &cfg8,
                &pm,
                &plan8,
                BatchConfig {
                    batch,
                    pipeline: true,
                    stream_weights,
                    ..BatchConfig::default()
                },
            )
        };
        let block = mk(false);
        let stream = mk(true);
        println!(
            "  batch {batch:>2}: blocking {:>6.2} -> streamed {:>6.2} inf/s ({:.2}x)",
            block.inferences_per_s(),
            stream.inferences_per_s(),
            stream.inferences_per_s() / block.inferences_per_s()
        );
    }
    bench("run_batched_staged_streamed_b4", 10, 500, || {
        run_batched(
            &net,
            Strategy::ImaDw,
            &cfg8,
            &pm,
            &plan8,
            BatchConfig {
                batch: 4,
                pipeline: true,
                stream_weights: true,
                ..BatchConfig::default()
            },
        )
    });
}
