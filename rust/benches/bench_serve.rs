//! Bench: the event-driven serving simulator — wall cost of simulating
//! multi-model traffic (the tool itself must stay interactive for sweeps),
//! histogram hot-path cost, and a peek at the latency tables per policy.

use imcc::arch::PowerModel;
use imcc::serve::{mnv2_bottleneck_pair as models, simulate, LogHistogram, Policy, ServeConfig};
use imcc::util::bench::bench;

fn main() {
    println!("== bench_serve (event-driven multi-model serving) ==");
    let pm = PowerModel::paper();

    // histogram hot path: record + quantile
    bench("histogram_record_4k", 20, 500, || {
        let mut h = LogHistogram::new();
        for v in 0..4096u64 {
            h.record(v * 37 + 11);
        }
        h.percentiles()
    });

    for &(label, rate) in &[("light", 50.0), ("saturated", 150.0), ("overload", 600.0)] {
        let ms = models(rate);
        let scfg = ServeConfig {
            duration_s: 0.1,
            ..ServeConfig::default()
        };
        bench(&format!("simulate_{label}_{rate}rps"), 5, 2000, || {
            simulate(&ms, &scfg, &pm).unwrap()
        });
    }

    println!("\nper-policy tables, 2 models, 0.1 s @ 150 req/s/model:");
    for policy in [Policy::Fifo, Policy::Wrr, Policy::Sjf] {
        let scfg = ServeConfig {
            policy,
            duration_s: 0.1,
            ..ServeConfig::default()
        };
        let rep = simulate(&models(150.0), &scfg, &pm).unwrap();
        print!("{}", rep.render_table());
    }
}
