//! Bench: the event-driven serving simulator — wall cost of simulating
//! multi-model traffic (the tool itself must stay interactive for sweeps),
//! the heap-based next-event queue at high tenant counts, histogram
//! hot-path cost, the overlapped-vs-serialized dispatch comparison,
//! weight-update streaming on a staged tenant, and the long-horizon
//! pruned-vs-unpruned timeline section (wall clock here; the
//! deterministic counter baseline lives in `imcc bench-timeline`).

use imcc::arch::PowerModel;
use imcc::coordinator::PlanCache;
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::serve::{
    bottleneck_fleet as tenant_fleet, mnv2_bottleneck_pair as models, simulate,
    simulate_with_cache, LogHistogram, ModelTraffic, Policy, ServeConfig, TrafficModel,
};
use imcc::util::bench::bench;

fn main() {
    println!("== bench_serve (event-driven multi-model serving) ==");
    let pm = PowerModel::paper();

    // histogram hot path: record + quantile
    bench("histogram_record_4k", 20, 500, || {
        let mut h = LogHistogram::new();
        for v in 0..4096u64 {
            h.record(v * 37 + 11);
        }
        h.percentiles()
    });

    for &(label, rate) in &[("light", 50.0), ("saturated", 150.0), ("overload", 600.0)] {
        let ms = models(rate);
        let scfg = ServeConfig {
            duration_s: 0.1,
            ..ServeConfig::default()
        };
        bench(&format!("simulate_{label}_{rate}rps"), 5, 2000, || {
            simulate(&ms, &scfg, &pm).unwrap()
        });
    }

    // heap-based next-event queue: wall cost vs tenant count (the former
    // linear scan re-examined every queue at every dispatch)
    let mut cache = PlanCache::with_capacity(256);
    for &n in &[4usize, 16, 32] {
        let ms = tenant_fleet(n, 100.0);
        let scfg = ServeConfig {
            n_arrays: 6 * n,
            duration_s: 0.05,
            ..ServeConfig::default()
        };
        bench(&format!("simulate_{n}_tenants"), 3, 2000, || {
            simulate_with_cache(&ms, &scfg, &pm, &mut cache).unwrap()
        });
    }

    println!("\ntwo-tenant dispatch, 0.1 s @ 150 req/s/model:");
    for (label, overlap, backfill) in [
        ("backfilled", true, true),
        ("envelope", true, false),
        ("serialized", false, false),
    ] {
        let scfg = ServeConfig {
            overlap,
            backfill,
            duration_s: 0.1,
            ..ServeConfig::default()
        };
        let rep = simulate(&models(150.0), &scfg, &pm).unwrap();
        println!(
            "  {label:>10}: makespan {:>8.2} ms, {:>7.1} inf/s, pool util {:.0}%",
            rep.makespan_cycles as f64 * rep.cycle_ns * 1e-6,
            rep.inferences_per_s(),
            rep.utilization() * 100.0
        );
    }

    // backfilling pays off where envelopes leave gaps: high offered load
    println!("\nbackfilled vs envelope makespan, 2 tenants, 0.05 s:");
    for &rate in &[300.0, 600.0, 1200.0] {
        let mut row = format!("  {rate:>6.0} req/s:");
        for (label, backfill) in [("envelope", false), ("backfilled", true)] {
            let scfg = ServeConfig {
                backfill,
                duration_s: 0.05,
                ..ServeConfig::default()
            };
            let rep = simulate(&models(rate), &scfg, &pm).unwrap();
            row.push_str(&format!(
                " {label} {:>8.2} ms ({:>6.1} inf/s)",
                rep.makespan_cycles as f64 * rep.cycle_ns * 1e-6,
                rep.inferences_per_s()
            ));
        }
        println!("{row}");
    }

    println!("\nstaged MobileNetV2 tenant (8 arrays), 0.05 s @ 20 req/s:");
    for (label, stream_weights) in [("blocking", false), ("streamed", true)] {
        let ms = vec![ModelTraffic {
            net: mobilenet_v2(224),
            traffic: TrafficModel::Poisson { rate_per_s: 20.0 },
            weight: 1,
        }];
        let scfg = ServeConfig {
            n_arrays: 8,
            stream_weights,
            duration_s: 0.05,
            ..ServeConfig::default()
        };
        let rep = simulate(&ms, &scfg, &pm).unwrap();
        println!(
            "  {label:>9}: makespan {:>8.2} ms, {:>6.2} inf/s",
            rep.makespan_cycles as f64 * rep.cycle_ns * 1e-6,
            rep.inferences_per_s()
        );
    }

    // long-horizon pruning: same dispatch table, less gap-search work —
    // wall clock here, counter deltas in the printed summary
    println!("\npruned vs --no-prune, 4 tenants @ 150 req/s, long horizons:");
    let mut prune_cache = PlanCache::with_capacity(64);
    let fleet = tenant_fleet(4, 150.0);
    for &duration_s in &[0.25f64, 1.0, 2.5] {
        let mut row = format!("  {duration_s:>5.2} s:");
        let mut probes = [0u64; 2];
        for (slot, prune) in [(0usize, true), (1usize, false)] {
            let scfg = ServeConfig {
                n_arrays: 24,
                prune,
                duration_s,
                ..ServeConfig::default()
            };
            let r = bench(
                &format!("simulate_{}_{duration_s}s", if prune { "pruned" } else { "noprune" }),
                2,
                3000,
                || simulate_with_cache(&fleet, &scfg, &pm, &mut prune_cache).unwrap(),
            );
            let rep = simulate_with_cache(&fleet, &scfg, &pm, &mut prune_cache).unwrap();
            probes[slot] = rep.counters.probes;
            row.push_str(&format!(
                " {} {:>9.3} ms wall, {:>9} probes, {:>6} live iv;",
                if prune { "pruned" } else { "no-prune" },
                r.median_ns / 1e6,
                rep.counters.probes,
                rep.counters.live_intervals
            ));
        }
        println!("{row} probe work x{:.2}", probes[1] as f64 / probes[0].max(1) as f64);
    }

    println!("\nper-policy tables, 2 models, 0.1 s @ 150 req/s/model:");
    for policy in [Policy::Fifo, Policy::Wrr, Policy::Sjf] {
        let scfg = ServeConfig {
            policy,
            duration_s: 0.1,
            ..ServeConfig::default()
        };
        let rep = simulate(&models(150.0), &scfg, &pm).unwrap();
        print!("{}", rep.render_table());
    }
}
