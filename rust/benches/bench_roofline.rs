//! Bench: Fig. 7 roofline regeneration + the underlying per-point cost.
//!
//! Regenerates the paper's roofline (the experiment itself) and reports how
//! long the simulator takes per roofline point and per full figure — the L3
//! hot path for design-space exploration.

use imcc::arch::{FreqPoint, PowerModel, SystemConfig};
use imcc::ima::ImaSubsystem;
use imcc::report::fig7_roofline;
use imcc::util::bench::bench;

fn main() {
    println!("== bench_roofline (Fig. 7) ==");
    let pm = PowerModel::paper();
    let cfg = SystemConfig::paper().with_freq(FreqPoint::LOW);

    bench("roofline_point_full_util", 50, 300, || {
        let ima = ImaSubsystem::new(&cfg, &pm);
        ima.roofline_point(256, 65536)
    });

    bench("roofline_point_low_util", 50, 300, || {
        let ima = ImaSubsystem::new(&cfg, &pm);
        ima.roofline_point(57, 65536)
    });

    let r = bench("fig7_all_panels", 5, 2000, fig7_roofline::generate);
    let _ = r;

    // the experiment result itself (printed so `cargo bench` logs carry it)
    let rep = fig7_roofline::generate();
    let peak = rep.data.req("peak_gops").as_f64().unwrap();
    println!("result: peak {peak:.0} GOPS (paper: 958)");
}
