//! Bench: the job-backend hot path — per-job latency of every kernel and
//! functional-inference throughput. This is the L3 §Perf target: the
//! request path must be backend-bound, not host-orchestration-bound.
//!
//! The per-job benches run anywhere (native backend); the tiny-network
//! throughput section needs `make artifacts` and is skipped without it.

use imcc::runtime::{functional, Manifest, Runtime};
use imcc::util::bench::bench;

fn main() {
    println!("== bench_runtime (job-backend hot path) ==");
    let dir = std::env::var("IMCC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let mut rt = Runtime::load(&dir).expect("native backend always loads");

    let w = vec![1i8; 256 * 256];
    rt.program_weight_tile((0, 0, 0), &w).unwrap();
    let x = vec![1i8; 16 * 256];
    let acc = vec![1000i32; 16 * 256];
    let a = vec![1i8; 4096];
    let dwx = vec![1i8; 18 * 18 * 16];
    let dww = vec![1i8; 9 * 16];

    bench("mvm_job_batch", 100, 1500, || {
        rt.mvm((0, 0, 0), &x, 8, true, 16).unwrap()
    });
    bench("mvm_raw_job_batch", 100, 1500, || {
        rt.mvm_raw((0, 0, 0), &x, 16).unwrap()
    });
    let x128 = vec![1i8; 128 * 256];
    bench("mvm_job_batch_128px", 50, 1500, || {
        rt.mvm((0, 0, 0), &x128, 8, true, 128).unwrap()
    });
    bench("requant", 100, 1000, || {
        rt.requant(&acc, 3, false, 16).unwrap()
    });
    bench("residual_chunk", 100, 1000, || {
        rt.residual(&a, &a).unwrap()
    });
    bench("dw_tile_s1", 100, 1000, || {
        rt.dw_tile(&dwx, &dww, 4, true, 1).unwrap()
    });

    // end-to-end functional throughput on the tiny network (needs artifacts)
    if !std::path::Path::new(&format!("{dir}/manifest_tiny.json")).exists() {
        println!("skipping tiny-net throughput: {dir}/manifest_tiny.json not found");
        return;
    }
    let m = Manifest::load(&dir, true).unwrap();
    functional::program_network(&mut rt, &m, 0.0).unwrap();
    let r = bench("tiny_net_inference", 5, 4000, || {
        functional::run_inference(&rt, &m).unwrap()
    });
    let res = functional::run_inference(&rt, &m).unwrap();
    println!(
        "result: tiny net = {} backend calls / inference, median {:.2} ms → {:.0} µs/job",
        res.backend_calls,
        r.median_ns / 1e6,
        r.median_ns / 1e3 / res.backend_calls as f64
    );
}
